"""Object classes and placement — the DAOS striping model.

DAOS distributes an object across *targets* (engine shards) according to its
object class: S1 places the object on one engine, S2 stripes it over two,
S4 over four, ... SX over every engine in the pool (analogous to Lustre file
striping).  Placement must be deterministic given the pool map version so that
any client can locate a shard without asking a server — we use Lamping &
Veach's jump consistent hash, which is also what gives S1/S2 their natural
load *imbalance* (the effect the paper measures).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


def jump_hash(key: int, n_buckets: int) -> int:
    """Jump consistent hash (Lamping & Veach 2014). Deterministic, minimal
    movement when n_buckets changes — the property DAOS pool maps need for
    incremental rebuild."""
    if n_buckets <= 0:
        raise ValueError("n_buckets must be positive")
    key &= (1 << 64) - 1
    b, j = -1, 0
    while j < n_buckets:
        b = j
        key = (key * 2862933555777941757 + 1) & ((1 << 64) - 1)
        j = int((b + 1) * (float(1 << 31) / float((key >> 33) + 1)))
    return b


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & ((1 << 64) - 1)
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4B5B9) & ((1 << 64) - 1)
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & ((1 << 64) - 1)
    return x ^ (x >> 31)


@dataclasses.dataclass(frozen=True)
class ObjectClass:
    """A DAOS object class: stripe width + redundancy.

    name        e.g. "S1", "S2", "SX", "RP_2GX", "EC_4P1"
    stripes     number of engine shards data is striped over (0 == all, i.e. SX)
    replicas    full-data replicas (RP_k)
    ec_data/ec_parity  erasure-coding group geometry (0 == no EC)
    """
    name: str
    stripes: int            # 0 means "X" = all engines in pool
    replicas: int = 1
    ec_data: int = 0
    ec_parity: int = 0

    def resolve_stripes(self, n_engines: int) -> int:
        k = n_engines if self.stripes == 0 else min(self.stripes, n_engines)
        return max(1, k)

    @property
    def protection_factor(self) -> float:
        """Bytes written to media per logical byte."""
        if self.ec_data:
            return (self.ec_data + self.ec_parity) / self.ec_data
        return float(self.replicas)


_REGISTRY: dict[str, ObjectClass] = {}


def register(oc: ObjectClass) -> ObjectClass:
    _REGISTRY[oc.name] = oc
    return oc


OC_S1 = register(ObjectClass("S1", 1))
OC_S2 = register(ObjectClass("S2", 2))
OC_S4 = register(ObjectClass("S4", 4))
OC_S8 = register(ObjectClass("S8", 8))
OC_SX = register(ObjectClass("SX", 0))
OC_RP_2G1 = register(ObjectClass("RP_2G1", 1, replicas=2))
OC_RP_2GX = register(ObjectClass("RP_2GX", 0, replicas=2))
OC_RP_3GX = register(ObjectClass("RP_3GX", 0, replicas=3))
OC_EC_4P1 = register(ObjectClass("EC_4P1", 4, ec_data=4, ec_parity=1))
OC_EC_8P1 = register(ObjectClass("EC_8P1", 8, ec_data=8, ec_parity=1))


def get_class(name: str) -> ObjectClass:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown object class {name!r}; known: {sorted(_REGISTRY)}")


@dataclasses.dataclass(frozen=True)
class StripeLayout:
    """Resolved placement of one object on a concrete pool map."""
    oid: int
    oclass: ObjectClass
    targets: tuple[int, ...]          # engine ids, one per (stripe × replica)
    stripe_cell: int                  # bytes per stripe cell

    @property
    def width(self) -> int:
        return len(self.targets) // max(1, self.oclass.replicas)

    def shard_for_chunk(self, chunk_no: int, replica: int = 0) -> int:
        w = self.width
        return self.targets[replica * w + (chunk_no % w)]

    def replicas_for_chunk(self, chunk_no: int) -> tuple[int, ...]:
        w = self.width
        return tuple(self.targets[r * w + (chunk_no % w)]
                     for r in range(self.oclass.replicas))


def place_object(oid: int, oclass: ObjectClass, engine_ids: Sequence[int],
                 map_version: int, stripe_cell: int = 1 << 20,
                 node_of: dict[int, int] | None = None) -> StripeLayout:
    """Deterministic placement of an object's shards on the pool's engines.

    Replicas of the same stripe are forced onto distinct engines (and distinct
    *nodes* when node_of is given and enough nodes exist) — DAOS's redundancy-
    group placement rule.
    """
    engines = list(engine_ids)
    n = len(engines)
    if n == 0:
        raise ValueError("pool has no live engines")
    k = oclass.resolve_stripes(n)
    seed = _splitmix64(oid ^ _splitmix64(map_version))
    start = jump_hash(seed, n)
    # Stripe shards are laid out round-robin from a hashed starting engine —
    # this is what creates hot spots for S1/S2 (paper claims C1/C2).
    primary = [engines[(start + i) % n] for i in range(k)]
    targets = list(primary)
    for r in range(1, oclass.replicas):
        for i in range(k):
            base = (start + i) % n
            stripe_engines = {targets[rr * k + i] for rr in range(r)}
            cand = None
            # prefer a different *node* (redundancy-group placement rule),
            # fall back to any different engine
            for prefer_other_node in (True, False):
                for shift in range(1, n + 1):
                    c = engines[(base + r * shift) % n]
                    if c in stripe_engines:
                        continue
                    if prefer_other_node and node_of and \
                            node_of[c] == node_of[primary[i]]:
                        continue
                    cand = c
                    break
                if cand is not None:
                    break
            targets.append(cand if cand is not None else primary[i])
    return StripeLayout(oid=oid, oclass=oclass, targets=tuple(targets),
                        stripe_cell=stripe_cell)


def oid_for(name: str | int, container_seq: int = 0) -> int:
    """Derive a 64-bit object id from a name (DFS path, array name, ...)."""
    if isinstance(name, int):
        return _splitmix64(name ^ _splitmix64(container_seq))
    h = 1469598103934665603  # FNV-1a 64
    for byte in name.encode():
        h = ((h ^ byte) * 1099511628211) & ((1 << 64) - 1)
    return _splitmix64(h ^ _splitmix64(container_seq))
