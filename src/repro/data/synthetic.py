"""Synthetic token data with learnable structure.

A fixed random affine recurrence over tokens (t_{i+1} = (a * t_i + b) % V
with per-position noise) gives a corpus with real conditional entropy — a
model that learns drops loss well below log V, so the end-to-end example
demonstrably trains (quickstart asserts it).
"""
from __future__ import annotations

import numpy as np


def synthetic_corpus(n_tokens: int, vocab: int, seed: int = 0,
                     noise: float = 0.1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = int(rng.integers(2, vocab - 1)) | 1
    b = int(rng.integers(1, vocab - 1))
    t = np.empty(n_tokens, np.int32)
    t[0] = rng.integers(0, vocab)
    for i in range(1, n_tokens):
        if rng.random() < noise:
            t[i] = rng.integers(0, vocab)
        else:
            t[i] = (a * int(t[i - 1]) + b) % vocab
    return t


def synthetic_batch(rng: np.random.Generator, corpus: np.ndarray,
                    batch: int, seq: int) -> dict:
    starts = rng.integers(0, corpus.size - seq - 1, batch)
    toks = np.stack([corpus[s: s + seq] for s in starts])
    return {"tokens": toks.astype(np.int32)}
