"""IOR-equivalent harness: the paper's Fig. 1 (file-per-process) and
Fig. 2 (single-shared-file) benchmark matrix.

Sweeps interface x object class x client-node count for write and read
phases, on the NEXTGenIO-like topology (8 servers x 2 engines).  Payloads
use the sized (synthetic) I/O path — placement, contention and per-op costs
are fully accounted without materialising hundreds of GiB.

Also draws the Lustre-model baseline for the paper's closing claim (C5):
file-per-process ~= shared-file on DAOS, while the POSIX-filesystem model
collapses on shared-file writes.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import Pool, Topology, bandwidth  # noqa: E402
from repro.core.baselines import LustreModel      # noqa: E402
from repro.core.interfaces import DFS, make_interface  # noqa: E402
from repro.core.object import IOCtx               # noqa: E402

GIB = 1 << 30
MIB = 1 << 20
KIB = 1 << 10

DEFAULT_CLASSES = ["S1", "S2", "S4", "SX"]
DEFAULT_IFACES = ["dfs", "mpiio", "hdf5", "posix"]
# cached-vs-uncached pairs (dfuse caching study, arXiv 2409.18682 axis)
DEFAULT_CACHED_IFACES = ["posix", "posix-cached", "posix-readahead",
                         "dfs", "dfs-cached"]
ARTIFACTS = pathlib.Path(__file__).resolve().parents[1] / "artifacts"


def make_world(oclass: str, ppn: int, clients: int):
    topo = Topology(n_server_nodes=8, engines_per_node=2,
                    n_client_nodes=clients, procs_per_client_node=ppn)
    pool = Pool(topo, materialize=False)
    cont = pool.create_container("bench", oclass=oclass)
    # benchmark namespace: S1 dirs (pure md-path, no replication cost)
    dfs = DFS(cont, dir_oclass="S1")
    dfs.mkdir("/ior")
    return pool, dfs


def ior_easy(pool, dfs, iface_name: str, oclass: str, clients: int,
             ppn: int, block: int, transfer: int) -> dict:
    """File-per-process: each rank writes/reads its own file."""
    iface = make_interface(iface_name, dfs)
    handles = {}
    with pool.sim.phase() as wph:
        for node in range(clients):
            for p in range(ppn):
                rank = node * ppn + p
                h = iface.create(f"/ior/easy_{rank}",
                                 oclass=oclass, client_node=node,
                                 process=rank)
                handles[rank] = h
                for off in range(0, block, transfer):
                    h.write_sized_at(off, transfer)
    with pool.sim.phase() as rph:
        for node in range(clients):
            for p in range(ppn):
                rank = node * ppn + p
                h = handles[rank]
                for off in range(0, block, transfer):
                    h.read_sized_at(off, transfer)
    total = clients * ppn * block
    return {"write_gib_s": bandwidth(total, wph.elapsed),
            "read_gib_s": bandwidth(total, rph.elapsed),
            "write_imbalance": round(wph.imbalance(), 3),
            "total_gib": total / GIB}


def ior_hard(pool, dfs, iface_name: str, oclass: str, clients: int,
             ppn: int, block: int, transfer: int) -> dict:
    """Single shared file: ranks write disjoint segments of one file.
    HDF5 on a shared file goes through its MPI-IO VFD (collective).

    Drives the object directly (no client-cache tier): DAOS guidance is to
    disable dfuse caching for write-shared files, so cached interface
    variants intentionally behave as their uncached base here."""
    iface = make_interface("hdf5-coll" if iface_name == "hdf5"
                           else iface_name, dfs)
    nprocs = clients * ppn
    fname = "/ior/hard"
    h0 = iface.create(fname, oclass=oclass, client_node=0, process=0)
    node_of = {r: r // ppn for r in range(nprocs)}

    collective = hasattr(iface, "write_all")
    with pool.sim.phase() as wph:
        if collective:
            pieces = {r: (r * block, block) for r in range(nprocs)}
            iface.write_all(h0, pieces, node_of)
        else:
            for r in range(nprocs):
                ctx = iface.make_ctx(node_of[r], r)
                for off in range(0, block, transfer):
                    h0.obj.write_sized(r * block + off, transfer, ctx=ctx)
    with pool.sim.phase() as rph:
        if collective:
            pieces = {r: (r * block, block) for r in range(nprocs)}
            iface.read_all(h0, pieces, node_of)
        else:
            for r in range(nprocs):
                ctx = iface.make_ctx(node_of[r], r)
                for off in range(0, block, transfer):
                    h0.obj.read_sized(r * block + off, transfer, ctx=ctx)
    total = nprocs * block
    return {"write_gib_s": bandwidth(total, wph.elapsed),
            "read_gib_s": bandwidth(total, rph.elapsed),
            "write_imbalance": round(wph.imbalance(), 3),
            "total_gib": total / GIB}


def ior_cached(pool, dfs, iface_name: str, oclass: str, clients: int,
               ppn: int, block: int, transfer: int) -> dict:
    """dfuse-caching study: small-transfer file-per-process workload with a
    re-read and a re-write pass — the access pattern client-side caching is
    built for (write-back coalesces the small sync writes; the page cache
    serves the re-reads locally)."""
    iface = make_interface(iface_name, dfs)
    handles = {}

    def sweep(op: str) -> float:
        with pool.sim.phase() as ph:
            for node in range(clients):
                for p in range(ppn):
                    rank = node * ppn + p
                    h = handles[rank]
                    for off in range(0, block, transfer):
                        if op == "write":
                            h.write_sized_at(off, transfer)
                        else:
                            h.read_sized_at(off, transfer)
                    if op == "write":
                        h.fsync()   # close/fsync flushes write-back data
        return ph.elapsed

    with pool.sim.phase():
        for node in range(clients):
            for p in range(ppn):
                rank = node * ppn + p
                handles[rank] = iface.create(f"/ior/c_{rank}", oclass=oclass,
                                             client_node=node, process=rank)
    total = clients * ppn * block
    t_w = sweep("write")
    t_rr = sweep("read")
    t_rw = sweep("write")
    row = {"write_gib_s": bandwidth(total, t_w),
           "re_read_gib_s": bandwidth(total, t_rr),
           "re_write_gib_s": bandwidth(total, t_rw),
           "total_gib": total / GIB}
    if getattr(iface, "cache_mode", "none") != "none":
        st = iface.cache_stats()
        hits, misses = st.get("read_hits", 0), st.get("read_misses", 0)
        row["cache"] = iface.cache_mode
        row["hit_rate"] = round(hits / max(1, hits + misses), 3)
        row["flushes"] = st.get("flushes", 0)
        row["wb_bytes_gib"] = round(st.get("wb_bytes", 0) / GIB, 2)
    else:
        row["cache"] = "none"
    return row


#: readahead-window (pages) x write-back-buffer (MiB) grid for the
#: transfer-size sweep — the cache-tuning axes of arXiv 2409.18682.
DEFAULT_WINDOWS = [(4, 4), (8, 16), (16, 64)]


def ior_sweep_cell(pool, dfs, iface_name: str, clients: int, ppn: int,
                   block: int, transfer: int) -> dict:
    """One sweep cell: write pass (wb_buffer sets flush granularity), a
    *cold* sequential read after the caches are dropped (fresh mount: the
    readahead window sets the miss rate), and a warm re-read."""
    iface = make_interface(iface_name, dfs)
    handles = {}
    with pool.sim.phase():
        for node in range(clients):
            for p in range(ppn):
                rank = node * ppn + p
                handles[rank] = iface.create(f"/ior/s_{rank}", oclass="SX",
                                             client_node=node, process=rank)

    def sweep(op: str) -> float:
        with pool.sim.phase() as ph:
            for node in range(clients):
                for p in range(ppn):
                    rank = node * ppn + p
                    h = handles[rank]
                    for off in range(0, block, transfer):
                        if op == "write":
                            h.write_sized_at(off, transfer)
                        else:
                            h.read_sized_at(off, transfer)
                    if op == "write":
                        h.fsync()
        return ph.elapsed

    total = clients * ppn * block
    t_w = sweep("write")
    iface.drop_caches()                                    # fresh mount
    t_cold = sweep("read")
    t_rr = sweep("read")
    row = {"write_gib_s": bandwidth(total, t_w),
           "cold_read_gib_s": bandwidth(total, t_cold),
           "re_read_gib_s": bandwidth(total, t_rr),
           "total_gib": total / GIB}
    if getattr(iface, "cache_mode", "none") != "none":
        st = iface.cache_stats()
        row["flushes"] = st.get("flushes", 0)
        row["readahead_gib"] = round(st.get("readahead_bytes", 0) / GIB, 2)
    return row


def ior_sweep(clients: int, ppn: int, block: int, transfers, windows
              ) -> list[dict]:
    """Transfer-size sweep (4 KiB - 4 MiB) x readahead/wb_buffer windows,
    following the arXiv 2409.18682 curve methodology: each cell runs
    write / cold-read / re-read through a mount-option-tuned cache
    (``posix-cached:readahead=R,wb_mib=W``) and is compared against the
    uncached posix floor at the same transfer size."""
    rows = []
    for transfer in transfers:
        cells = [("posix", "uncached", None, None)]
        for ra, wb in windows:
            cells.append((f"posix-cached:readahead={ra},wb_mib={wb}",
                          f"ra{ra}/wb{wb}", ra, wb))
        for name, window, ra, wb in cells:
            pool, dfs = make_world("SX", ppn, clients)
            res = ior_sweep_cell(pool, dfs, name, clients, ppn, block,
                                 transfer)
            rows.append({"mode": "sweep", "oclass": "SX", "interface": name,
                         "window": window, "readahead_pages": ra,
                         "wb_mib": wb, "clients": clients, "ppn": ppn,
                         "block_mib": block // MIB,
                         "transfer_kib": transfer / KIB, **res})
    return rows


def print_sweep(rows: list[dict]) -> None:
    srows = [r for r in rows if r.get("mode") == "sweep"]
    if not srows:
        return
    transfers = sorted({r["transfer_kib"] for r in srows})
    windows = sorted({r["window"] for r in srows})
    for metric in ("write_gib_s", "cold_read_gib_s", "re_read_gib_s"):
        print(f"\n=== IOR transfer-size sweep: {metric} (GiB/s) ===")
        print(f"{'window':12s}" + "".join(f"{t:>9.0f}K" for t in transfers))
        for w in windows:
            vals = []
            for t in transfers:
                v = [r for r in srows if r["window"] == w
                     and r["transfer_kib"] == t]
                vals.append(f"{v[0][metric]:10.1f}" if v else " " * 10)
            print(f"{w:12s}" + "".join(vals))


def run_matrix(mode: str, classes, ifaces, client_counts, ppn: int,
               block: int, transfer: int) -> list[dict]:
    rows = []
    fn = {"easy": ior_easy, "hard": ior_hard, "cached": ior_cached}[mode]
    for oclass in classes:
        for iface in ifaces:
            for clients in client_counts:
                pool, dfs = make_world(oclass, ppn, clients)
                res = fn(pool, dfs, iface, oclass, clients, ppn, block,
                         transfer)
                rows.append({"mode": mode, "oclass": oclass,
                             "interface": iface, "clients": clients,
                             "ppn": ppn, "block_mib": block // MIB,
                             "transfer_mib": transfer / MIB, **res})
    return rows


def lustre_rows(client_counts, ppn: int, block: int, transfer: int):
    lm = LustreModel()
    rows = []
    for mode in ("easy", "hard"):
        for clients in client_counts:
            if mode == "easy":
                w = lm.easy_bandwidth(clients, ppn, block, "write")
                r = lm.easy_bandwidth(clients, ppn, block, "read")
            else:
                w = lm.hard_bandwidth(clients, ppn, block, transfer, "write")
                r = lm.hard_bandwidth(clients, ppn, block, transfer, "read")
            rows.append({"mode": mode, "oclass": "lustre-16ost",
                         "interface": "lustre-posix", "clients": clients,
                         "ppn": ppn,
                         "write_gib_s": w / GIB, "read_gib_s": r / GIB})
    return rows


def print_table(rows, metric: str) -> None:
    counts = sorted({r["clients"] for r in rows})
    keys = sorted({(r["oclass"], r["interface"]) for r in rows})
    hdr = "mode  " + f"{'class':8s}{'iface':12s}" + "".join(
        f"{c:>9d}" for c in counts)
    print(hdr)
    mode = rows[0]["mode"]
    for oc, iface in keys:
        vals = []
        for c in counts:
            v = [r for r in rows if r["oclass"] == oc
                 and r["interface"] == iface and r["clients"] == c]
            vals.append(f"{v[0][metric]:9.1f}" if v else " " * 9)
        print(f"{mode:5s} {oc:8s}{iface:12s}" + "".join(vals))


def check_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    """Validate the paper's §IV findings against our reproduction."""
    def get(mode, oc, iface, clients, metric):
        for r in rows:
            if (r["mode"], r["oclass"], r["interface"],
                    r["clients"]) == (mode, oc, iface, clients):
                return r[metric]
        return None

    cmax = max(r["clients"] for r in rows if r["interface"] != "lustre-posix")
    out = []

    # C1: file-per-process read — S2 best
    s1 = get("easy", "S1", "dfs", cmax, "read_gib_s")
    s2 = get("easy", "S2", "dfs", cmax, "read_gib_s")
    sx = get("easy", "SX", "dfs", cmax, "read_gib_s")
    if None not in (s1, s2, sx):
        out.append(("C1 easy-read: S2 >= S1 and S2 > SX",
                    s2 >= s1 * 0.98 and s2 > sx,
                    f"S1={s1:.1f} S2={s2:.1f} SX={sx:.1f}"))

    # C2: file-per-process write — SX best only at the largest client count
    w2_hi = get("easy", "S2", "dfs", cmax, "write_gib_s")
    wx_hi = get("easy", "SX", "dfs", cmax, "write_gib_s")
    lo = min(r["clients"] for r in rows if r["interface"] == "dfs")
    w2_lo = get("easy", "S2", "dfs", lo, "write_gib_s")
    wx_lo = get("easy", "SX", "dfs", lo, "write_gib_s")
    if None not in (w2_hi, wx_hi, w2_lo, wx_lo):
        out.append(("C2 easy-write: SX wins at max clients, S2 >= SX early",
                    wx_hi > w2_hi and w2_lo >= wx_lo * 0.98,
                    f"hi: S2={w2_hi:.1f} SX={wx_hi:.1f}; "
                    f"lo: S2={w2_lo:.1f} SX={wx_lo:.1f}"))

    # C3: easy — dfs ~ mpiio, hdf5 much lower
    d = get("easy", "S2", "dfs", cmax, "write_gib_s")
    m = get("easy", "S2", "mpiio", cmax, "write_gib_s")
    h = get("easy", "S2", "hdf5", cmax, "write_gib_s")
    if None not in (d, m, h):
        out.append(("C3 easy: mpiio within 25% of dfs, hdf5 <= 60% of dfs",
                    abs(m - d) / d < 0.25 and h <= 0.6 * d,
                    f"dfs={d:.1f} mpiio={m:.1f} hdf5={h:.1f}"))

    # C4: shared-file — interfaces converge; DFS highest write
    vals = {i: get("hard", "SX", i, cmax, "write_gib_s")
            for i in ("dfs", "mpiio", "hdf5")}
    if None not in vals.values():
        spread = (max(vals.values()) - min(vals.values())) \
            / max(vals.values())
        out.append(("C4 hard: interface spread < 50%, dfs highest write",
                    spread < 0.5 and vals["dfs"] >= max(vals.values()) * 0.999,
                    " ".join(f"{k}={v:.1f}" for k, v in vals.items())))

    # C5: easy ~ hard on DAOS; Lustre-model hard write collapses
    de = get("easy", "SX", "dfs", cmax, "write_gib_s")
    dh = get("hard", "SX", "dfs", cmax, "write_gib_s")
    le = get("easy", "lustre-16ost", "lustre-posix", cmax, "write_gib_s")
    lh = get("hard", "lustre-16ost", "lustre-posix", cmax, "write_gib_s")
    if None not in (de, dh, le, lh):
        out.append(("C5 DAOS hard within 15% of easy; Lustre hard < 40% easy",
                    abs(dh - de) / de < 0.15 and lh < 0.4 * le,
                    f"daos {de:.1f}/{dh:.1f}; lustre {le:.1f}/{lh:.1f}"))
    return out


def check_cache_claims(rows: list[dict]) -> list[tuple[str, bool, str]]:
    """Validate the dfuse-caching finding (arXiv 2409.18682 axis): client
    caching must lift small-transfer POSIX re-read/re-write >= 3x.

    Evaluated at the *smallest* client count: caching removes client-side
    interface overhead, so its win is largest where that overhead is the
    bottleneck.  At large client counts every interface converges on the
    server fabric (the paper's C4 convergence) and the write-side gain
    honestly shrinks toward the fabric ceiling."""
    crows = [r for r in rows if r["mode"] == "cached"]
    if not crows:
        return []
    cmin = min(r["clients"] for r in crows)

    def get(iface, metric):
        for r in crows:
            if r["interface"] == iface and r["clients"] == cmin:
                return r[metric]
        return None

    out = []
    base_rr = get("posix", "re_read_gib_s")
    base_rw = get("posix", "re_write_gib_s")
    c_rr = get("posix-cached", "re_read_gib_s")
    c_rw = get("posix-cached", "re_write_gib_s")
    if None not in (base_rr, base_rw, c_rr, c_rw):
        out.append(("C6 posix-cached re-read/re-write >= 3x uncached posix",
                    c_rr >= 3 * base_rr and c_rw >= 3 * base_rw,
                    f"re-read {base_rr:.1f}->{c_rr:.1f} "
                    f"({c_rr / base_rr:.1f}x); re-write "
                    f"{base_rw:.1f}->{c_rw:.1f} ({c_rw / base_rw:.1f}x)"))
    ra_rr = get("posix-readahead", "re_read_gib_s")
    ra_rw = get("posix-readahead", "re_write_gib_s")
    if None not in (ra_rr, ra_rw, base_rr, base_rw):
        out.append(("C7 readahead lifts re-reads but not writes",
                    ra_rr >= 2 * base_rr and ra_rw <= 1.1 * base_rw,
                    f"re-read {ra_rr / base_rr:.1f}x, "
                    f"re-write {ra_rw / base_rw:.1f}x"))
    return out


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["easy", "hard", "cached", "sweep",
                                       "both", "all"],
                    default="both")
    ap.add_argument("--classes", nargs="+", default=DEFAULT_CLASSES)
    ap.add_argument("--interfaces", nargs="+", default=DEFAULT_IFACES)
    ap.add_argument("--cached-interfaces", nargs="+",
                    default=DEFAULT_CACHED_IFACES)
    ap.add_argument("--clients", nargs="+", type=int,
                    default=[1, 2, 4, 8, 16])
    ap.add_argument("--ppn", type=int, default=8)
    ap.add_argument("--block-mib", type=int, default=256)
    ap.add_argument("--transfer-mib", type=float, default=4)
    # the caching study is a *small-transfer* workload by design
    ap.add_argument("--cached-block-mib", type=int, default=64)
    ap.add_argument("--cached-transfer-kib", type=int, default=64)
    # the transfer-size sweep (4 KiB - 4 MiB, arXiv 2409.18682 curves)
    ap.add_argument("--sweep-transfers-kib", nargs="+", type=float,
                    default=[4, 16, 64, 256, 1024, 4096])
    ap.add_argument("--sweep-block-mib", type=int, default=16)
    ap.add_argument("--sweep-clients", type=int, default=2)
    ap.add_argument("--sweep-ppn", type=int, default=4)
    ap.add_argument("--baseline", choices=["lustre", "none"],
                    default="lustre")
    ap.add_argument("--out", default=str(ARTIFACTS / "ior_results.json"))
    args = ap.parse_args(argv)

    block = args.block_mib * MIB
    transfer = int(args.transfer_mib * MIB)
    modes = {"both": ["easy", "hard"],
             "all": ["easy", "hard", "cached", "sweep"]}.get(args.mode,
                                                             [args.mode])
    all_rows = []
    for mode in modes:
        if mode == "sweep":
            rows = ior_sweep(args.sweep_clients, args.sweep_ppn,
                             args.sweep_block_mib * MIB,
                             [int(t * KIB) for t in args.sweep_transfers_kib],
                             DEFAULT_WINDOWS)
            all_rows.extend(rows)
            print_sweep(rows)
            continue
        if mode == "cached":
            rows = run_matrix("cached", ["SX"], args.cached_interfaces,
                              args.clients, args.ppn,
                              args.cached_block_mib * MIB,
                              args.cached_transfer_kib * KIB)
            all_rows.extend(rows)
            for metric in ("write_gib_s", "re_read_gib_s", "re_write_gib_s"):
                print(f"\n=== IOR cached {metric} (GiB/s) ===")
                print_table(rows, metric)
            continue
        rows = run_matrix(mode, args.classes, args.interfaces, args.clients,
                          args.ppn, block, transfer)
        all_rows.extend(rows)
        for metric in ("write_gib_s", "read_gib_s"):
            print(f"\n=== IOR {mode} {metric} (GiB/s) ===")
            print_table(rows, metric)
    if args.baseline == "lustre":
        lrows = lustre_rows(args.clients, args.ppn, block, transfer)
        all_rows.extend(lrows)
        print("\n=== Lustre-model baseline (write GiB/s) ===")
        for mode in modes:
            rs = [r for r in lrows if r["mode"] == mode]
            print(mode, [round(r["write_gib_s"], 1) for r in rs])
    if args.mode in ("both", "all"):
        print("\n=== Paper-claims validation (§IV) ===")
        for name, ok, detail in check_claims(all_rows):
            print(f"  [{'PASS' if ok else 'FAIL'}] {name}   ({detail})")
    cache_checks = check_cache_claims(all_rows)
    if cache_checks:
        print("\n=== Caching-claims validation (dfuse study) ===")
        for name, ok, detail in cache_checks:
            print(f"  [{'PASS' if ok else 'FAIL'}] {name}   ({detail})")
    pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(args.out).write_text(json.dumps(all_rows, indent=1))
    print(f"\nsaved {len(all_rows)} rows -> {args.out}")
    return all_rows


if __name__ == "__main__":
    main()
