"""Public jit'd wrappers around the Pallas kernels.

These own all the padding/reshaping so the kernels only ever see aligned
tiles, and they pick interpret mode automatically (interpret=True on CPU,
compiled on TPU).  The host-side entry points (``checksum_array``) reproduce
``repro.core.integrity.checksum`` exactly, including the length mix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .checksum import TILE, TILE_COLS, TILE_ROWS, checksum_words_pallas
from .quantize import BLOCK_GROUPS, GROUP, dequantize_pallas, quantize_pallas
from .shard_pack import CELL_COLS, shard_pack_pallas, shard_unpack_pallas

_MASK64 = (1 << 64) - 1


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4B5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)


@functools.lru_cache(maxsize=4)
def _weights_tile() -> np.ndarray:
    return np.asarray(ref.weight_powers(TILE)).reshape(TILE_ROWS, TILE_COLS)


@functools.lru_cache(maxsize=256)
def _tile_scales(n_tiles: int) -> np.ndarray:
    w_tile = pow(int(ref.WEIGHT), TILE, 1 << 32)
    out = np.empty(n_tiles, np.uint32)
    acc = 1
    for i in range(n_tiles):
        out[i] = acc
        acc = (acc * w_tile) & 0xFFFFFFFF
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def _checksum_words_device(words: jnp.ndarray, scales: jnp.ndarray,
                           weights: jnp.ndarray,
                           interpret: bool = True) -> jnp.ndarray:
    n = words.shape[0]
    pad = (-n) % TILE
    if pad:
        words = jnp.concatenate([words, jnp.zeros(pad, jnp.uint32)])
    n_tiles = words.shape[0] // TILE
    out = checksum_words_pallas(
        words.reshape(n_tiles * TILE_ROWS, TILE_COLS),
        scales, weights, interpret=interpret)
    return out[0, 0]


def checksum_array(x, interpret: bool | None = None) -> int:
    """Device-side checksum of any array; bit-identical to
    ``repro.core.integrity.checksum`` of the array's bytes."""
    interpret = _interpret() if interpret is None else interpret
    arr = np.ascontiguousarray(np.asarray(x))
    nbytes = arr.nbytes
    if nbytes == 0:
        return 0 ^ (_splitmix64(0) & 0xFFFFFFFF)
    u8 = jnp.asarray(arr.view(np.uint8).reshape(-1))
    words = ref.bytes_to_words(u8)
    n_tiles = -(-int(words.shape[0]) // TILE)
    acc = int(_checksum_words_device(words, _tile_scales(n_tiles),
                                     _weights_tile(), interpret=interpret))
    return acc ^ (_splitmix64(nbytes) & 0xFFFFFFFF)


# ----------------------------- quantisation -----------------------------

@functools.partial(jax.jit, static_argnames=("interpret",))
def _quant_groups(flat: jnp.ndarray, interpret: bool = True):
    n = flat.shape[0]
    pad = (-n) % (GROUP * BLOCK_GROUPS)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros(pad, jnp.float32)])
    return quantize_pallas(flat.reshape(-1, GROUP), interpret=interpret)


def quantize(x: jnp.ndarray, interpret: bool | None = None):
    """-> (q int8 [n_groups, GROUP], scales [n_groups, 1], meta) where meta
    carries the original shape/dtype/length for dequantize()."""
    interpret = _interpret() if interpret is None else interpret
    meta = (x.shape, x.dtype, int(np.prod(x.shape)) if x.shape else 1)
    q, s = _quant_groups(jnp.asarray(x, jnp.float32).reshape(-1),
                         interpret=interpret)
    return q, s, meta


@functools.partial(jax.jit, static_argnames=("interpret",))
def _dequant_groups(q: jnp.ndarray, s: jnp.ndarray, interpret: bool = True):
    return dequantize_pallas(q, s, interpret=interpret)


def dequantize(q: jnp.ndarray, scales: jnp.ndarray, meta,
               interpret: bool | None = None) -> jnp.ndarray:
    interpret = _interpret() if interpret is None else interpret
    shape, dtype, n = meta
    flat = _dequant_groups(q, scales, interpret=interpret).reshape(-1)
    return flat[:n].reshape(shape).astype(dtype)


# ----------------------------- stripe packing -----------------------------

def shard_pack(x: jnp.ndarray, width: int, cell_bytes: int = 1 << 16,
               interpret: bool | None = None):
    """Reorder a flat byte buffer into per-target stripe buffers.

    -> (packed (width, cells_per_target, cell_rows, 128) uint32, meta).
    cell_bytes must be a multiple of 512 (=128 lanes x 4 B).
    """
    interpret = _interpret() if interpret is None else interpret
    assert cell_bytes % (CELL_COLS * 4) == 0
    cell_words = cell_bytes // 4
    cell_rows = cell_words // CELL_COLS
    arr = np.ascontiguousarray(np.asarray(x))
    u8 = jnp.asarray(arr.view(np.uint8).reshape(-1))
    words = ref.bytes_to_words(u8)
    n = words.shape[0]
    pad = (-n) % (cell_words * width)
    if pad:
        words = jnp.concatenate([words, jnp.zeros(pad, jnp.uint32)])
    cells = words.reshape(-1, cell_rows, CELL_COLS)
    packed = shard_pack_pallas(cells, width, interpret=interpret)
    meta = (arr.nbytes, cell_bytes, width)
    return packed, meta


def shard_unpack(packed: jnp.ndarray, meta,
                 interpret: bool | None = None) -> np.ndarray:
    """Inverse: -> original raw bytes as np.uint8[orig_nbytes]."""
    interpret = _interpret() if interpret is None else interpret
    orig_nbytes, cell_bytes, width = meta
    cells = shard_unpack_pallas(packed, interpret=interpret)
    words = np.asarray(cells).reshape(-1).astype(np.uint32)
    u8 = words.view(np.uint8)  # little-endian round trip
    return u8[:orig_nbytes]


# ----------------------------- flash attention -----------------------------
# Model-facing wrapper over kernels/flash_attention.py: handles the
# (B,S,Hq,D) <-> (B,n_kv,G,S,D) layout, pads head_dim to 128, and provides
# the custom VJP (backward = the two Pallas backward kernels).

def _pad_d(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    D = x.shape[-1]
    pad = (-D) % 128
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
    return x, D


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def pallas_flash_attention(q, k, v, n_kv: int, causal: bool = True,
                           window: int = 0, prefix: int = 0,
                           bq: int = 256, bk: int = 512):
    """q: (B,S,Hq,D); k,v: (B,Sk,n_kv,D) -> (B,S,Hq,D)."""
    out, _ = _pallas_flash_fwd(q, k, v, n_kv, causal, window, prefix, bq, bk)
    return out


def _to_kernel_layout(q, k, v, n_kv):
    B, S, Hq, D = q.shape
    G = Hq // n_kv
    q5 = q.reshape(B, S, n_kv, G, D).transpose(0, 2, 3, 1, 4)
    k4 = k.transpose(0, 2, 1, 3)
    v4 = v.transpose(0, 2, 1, 3)
    return q5, k4, v4, G


def _pallas_flash_fwd(q, k, v, n_kv, causal, window, prefix, bq, bk):
    from .flash_attention import flash_fwd_pallas
    B, S, Hq, D = q.shape
    q5, k4, v4, G = _to_kernel_layout(q, k, v, n_kv)
    q5, D0 = _pad_d(q5)
    k4, _ = _pad_d(k4)
    v4, _ = _pad_d(v4)
    out5, lse = flash_fwd_pallas(q5, k4, v4, causal=causal, window=window,
                                 prefix=prefix, bq=bq, bk=bk,
                                 scale=1.0 / float(np.sqrt(D0)),
                                 interpret=_interpret())
    out = out5[..., :D0].transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D0)
    return out, (q, k, v, out, lse)


def _pallas_flash_bwd(n_kv, causal, window, prefix, bq, bk, res, dout):
    from .flash_attention import flash_bwd_pallas
    q, k, v, out, lse = res
    B, S, Hq, D = q.shape
    q5, k4, v4, G = _to_kernel_layout(q, k, v, n_kv)
    do5 = dout.reshape(B, S, n_kv, G, D).transpose(0, 2, 3, 1, 4)
    o5 = out.reshape(B, S, n_kv, G, D).transpose(0, 2, 3, 1, 4)
    delta = jnp.sum(do5.astype(jnp.float32) * o5.astype(jnp.float32),
                    axis=-1)
    q5, D0 = _pad_d(q5)
    k4, _ = _pad_d(k4)
    v4, _ = _pad_d(v4)
    do5, _ = _pad_d(do5)
    dq5, dk4, dv4 = flash_bwd_pallas(q5, k4, v4, do5, lse, delta,
                                     causal=causal, window=window,
                                     prefix=prefix, bq=bq, bk=bk,
                                     scale=1.0 / float(np.sqrt(D)),
                                     interpret=_interpret())
    dq = dq5[..., :D0].transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D0) \
        .astype(q.dtype)
    dk = dk4[..., :D0].transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv4[..., :D0].transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


pallas_flash_attention.defvjp(_pallas_flash_fwd, _pallas_flash_bwd)
