"""Serving demo: batched prefill+decode, with the model weights pulled from
an object-store checkpoint and the KV cache offloaded/restored through the
DAOS-model array API between "sessions" (the paper's fine-grained-I/O use
case).

    PYTHONPATH=src python examples/serve_kvcache.py
"""
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.core import Pool, Topology, bandwidth
from repro.core.interfaces import DFS, make_interface
from repro.ckpt import Checkpointer
from repro.models import init_model
from repro.serve import make_decode_step, make_prefill_step


def tree_bytes(t):
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(t))


def main() -> None:
    cfg = dataclasses.replace(smoke_variant(get_arch("chatglm3-6b")),
                              vocab_size=256)
    key = jax.random.PRNGKey(0)

    pool = Pool(Topology())
    dfs = DFS(pool.create_container("serve", oclass="S2"))

    # publish weights to the store; the serving fleet restores from there
    trained = init_model(key, cfg)
    ck = Checkpointer(dfs, interface="dfs", oclass="RP_2GX", n_writers=8)
    ck.save(0, trained)
    params = jax.tree.map(jnp.asarray, ck.restore(0, trained))
    print(f"weights via object store: {tree_bytes(params) / 2**20:.1f} MiB")

    # batched requests: prefill a prompt batch, decode greedily
    B, S = 4, 24
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg, pad_to=S + 16))
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    for t in range(8):
        tok, lg, cache = decode(params, cache, tok,
                                jnp.asarray(S + t, jnp.int32))
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print("generated tokens:\n", np.asarray(gen))

    # offload the KV cache between sessions through the array API
    iface = make_interface("daos-array", dfs)
    flat, tree = jax.tree.flatten(cache)
    with pool.sim.phase() as ph:
        for i, leaf in enumerate(flat):
            h = iface.create(f"/kvcache/sess0/leaf{i}", client_node=i % 8,
                             process=i)
            h.write_at(0, np.asarray(leaf))
    nbytes = sum(np.asarray(x).nbytes for x in flat)
    print(f"kv cache offload: {nbytes / 2**20:.1f} MiB at "
          f"{bandwidth(nbytes, ph.elapsed):.1f} GiB/s (modeled)")

    restored = []
    for i, leaf in enumerate(flat):
        h = iface.open(f"/kvcache/sess0/leaf{i}")
        raw = np.asarray(h.read_at(0, np.asarray(leaf).nbytes))
        arr = raw.view(np.asarray(leaf).dtype).reshape(leaf.shape)
        restored.append(jnp.asarray(arr))
    cache2 = jax.tree.unflatten(tree, restored)

    # decoding from the restored cache must continue identically
    t1, _, _ = decode(params, cache, tok, jnp.asarray(S + 8, jnp.int32))
    t2, _, _ = decode(params, cache2, tok, jnp.asarray(S + 8, jnp.int32))
    assert np.array_equal(np.asarray(t1), np.asarray(t2))
    print("restored KV cache decodes identically — session resumed.")


if __name__ == "__main__":
    main()
