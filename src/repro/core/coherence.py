"""Pluggable client-cache coherence policies.

The follow-up paper ("Exploring DAOS Interfaces and Performance",
arXiv 2409.18682) shows that the dfuse caching knob is not a boolean: under
multi-client *write-sharing* the caching advantage inverts — beyond some
sharer count, caching OFF wins.  Modeling that requires coherence to be a
policy axis of the cache tier, not a hardcoded scheme.  Three policies:

* ``broadcast`` — a write or punch that reaches the object layer eagerly
  pushes an invalidation into every attached cache that holds the object
  (except the writer's own).  Delivery is *costed*: each message charges
  the origin process a blocking round trip and the recipient node an
  upcall (``HWProfile.coh_msg_time``/``coh_msg_bytes``) — a strict
  coherence protocol, no longer the free oracle of the original CO1
  study (set both knobs to 0 to recover it).  Invalidation is
  page-granular: only the pages overlapping the written extent drop.
* ``timeout`` — what dfuse actually does (``attr-timeout`` /
  ``dentry-timeout``): cached attrs/dentries/pages are served without any
  coherence traffic until their lease expires; an expired page is then
  *revalidated* against an engine-side version token — a cheap round trip
  (``HWProfile.reval_op_time``, no payload, no media time) that either
  renews the lease (token unchanged) or drops the page (token moved:
  someone else wrote).  Leases, tokens and staleness are all tracked
  *per page*: revalidation compares only the extent sub-tokens of the
  touched pages, so a foreign write elsewhere in the object renews
  rather than drops.  Staleness is bounded by the timeout per page.
* ``off`` — direct I/O (dfuse caching disabled): the interface layer
  creates no cache at all, so every op is byte-for-byte the uncached
  interface.  Handled in ``AccessInterface`` (there is nothing for a
  policy object to do); :func:`make_policy` returns ``None`` for it.

Mixed-policy fleets: two mounts of one container may carry *different*
policies (e.g. ``posix-cached:coherence=timeout`` readers sharing a
container with ``posix:coherence=off`` writers).  The semantics fall out
of the layering and are guaranteed here:

* **off-writers still bump engine tokens** — version tokens live on the
  engines and move on every ``update``/``update_hole``/``punch``,
  regardless of whether the writer has a cache, so timeout-policy caches
  revalidate correctly against direct-I/O writers;
* **broadcast caches still hear about off-writers** —
  ``Container.notify_write``/``notify_punch`` fire for every object-layer
  mutation; an uncached writer has ``origin=None``, so no cache mistakes
  the event for its own flush;
* **each cache applies its own policy** — one event can simultaneously
  invalidate a broadcast cache's overlapping pages (charging delivery)
  and merely mark a timeout cache's pages stale (free).

Decision vs mechanism: the *policies* here decide what a notification or
an expired lease means; the *mechanisms* (dropping pages, trimming valid
ranges to owned dirty extents, dentry eviction) stay on ``ClientCache``.
``Container.notify_write``/``notify_punch`` route every event — carrying
the touched ``(offset, nbytes)`` extent — through the attached caches'
policies; neither ``Container`` nor ``ClientCache`` hardcodes an
invalidation scheme anymore.

Version-token protocol: every engine keeps a tiny monotonic counter per
(container, object) plus per-extent sub-counters keyed by (dkey, akey) —
for arrays that is one counter per stripe cell — all bumped by
``update``/``update_hole``/``punch``; a read fill piggybacks the current
tokens onto the response for free.  Revalidation of a page compares the
remembered sub-token sum of the cells the page overlaps
(:func:`extent_token`) against the engines' current sum (counters only
grow, so any foreign mutation inside the extent moves it; mutations
outside leave it alone).  Transaction semantics are policy-independent:
the commit barrier (``flush_tx``) and abort (``drop_tx``) act on staged
cache state directly, and sibling writes of one open transaction are
never treated as foreign by any policy.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class CoherenceStats:
    """Coherence *traffic* and *staleness* accounting for one policy."""
    invalidations_sent: int = 0    # broadcast messages delivered to caches
    invalidations_applied: int = 0  # messages that actually dropped pages
    revalidations: int = 0         # version-token round trips (data entries)
    reval_hits: int = 0            # lease renewed, cached data still valid
    reval_misses: int = 0          # token moved: pages dropped, re-fetch
    dentry_revalidations: int = 0  # version-token round trips (dentries)
    stale_hits: int = 0            # hits served after a foreign write
    max_staleness_s: float = 0.0   # oldest foreign-stale data ever served
    expired: int = 0               # entries dropped on expiry w/o a token

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def messages(self) -> int:
        """Total coherence traffic in messages — the CO2 metric."""
        return (self.invalidations_sent + self.revalidations
                + self.dentry_revalidations)


def object_token(obj) -> int:
    """Current engine-side version token of an object: the SUM of the live
    target engines' per-object counters.  Counters only grow, so any
    mutation (update / sized update / punch) on any shard moves the sum —
    a max would miss mutations landing on a different shard than earlier
    ones (KV dkeys hash across engines).  An engine death shrinks the sum,
    which fails conservative: the next revalidation drops the entry.  Pure
    model state — the caller charges the round trip
    (``IOSim.record_reval``) when the lookup is real traffic and not
    piggybacked on a fill."""
    tok = 0
    cont = obj.container
    for eid in set(obj._layout().targets):
        eng = obj.pool.engines[eid]
        if eng.alive:
            tok += eng.version_token(cont.label, obj.oid)
    return tok


def extent_tokens(obj, extents) -> list[int]:
    """Version tokens for a batch of byte extents with one layout/engine
    walk: each is the sum of the live target engines' sub-tokens over the
    stripe cells [lo, hi) overlaps.  Same monotonicity/conservativeness
    argument as :func:`object_token`, restricted to the extent — the
    primitive that makes revalidation page-granular (a foreign write to a
    disjoint stripe leaves it unchanged)."""
    sc = obj.stripe_cell
    cont = obj.container
    engines = [obj.pool.engines[eid] for eid in set(obj._layout().targets)
               if obj.pool.engines[eid].alive]
    out = []
    for lo, hi in extents:
        subs = [("arr", c)
                for c in range(lo // sc, max(lo // sc + 1, -(-hi // sc)))]
        out.append(sum(e.extent_token(cont.label, obj.oid, subs)
                       for e in engines))
    return out


def extent_token(obj, lo: int, hi: int) -> int:
    """Version token of one byte extent (see :func:`extent_tokens`)."""
    return extent_tokens(obj, [(lo, hi)])[0]


def _primary_live_engine(obj) -> int | None:
    for eid in obj._layout().targets:
        if obj.pool.engines[eid].alive:
            return eid
    return None


def _tx_sibling(entry, epoch) -> bool:
    """A write from a sibling rank of the same *open* transaction (shared-
    file checkpoint: many nodes, disjoint ranges, one epoch) is coordinated,
    not foreign — no policy treats it as a coherence event."""
    return (entry is not None and entry.tx is not None
            and getattr(entry.tx, "state", None) == "open"
            and getattr(entry.tx, "epoch", None) == epoch)


class CoherencePolicy:
    """Decision surface between ``Container`` notifications and one
    ``ClientCache``'s read path.  One instance per cache (policies keep
    per-cache staleness bookkeeping); stats are aggregated per interface
    by ``AccessInterface.coherence_stats``."""

    kind: str = "?"

    def __init__(self) -> None:
        self.stats = CoherenceStats()

    # ---- container-side notifications ----
    def remote_write(self, cache, name: str, epoch: int, origin,
                     now: float, offset: int = 0, nbytes: int | None = None,
                     ctx=None) -> None:
        raise NotImplementedError

    @staticmethod
    def _deliver(cache, ctx) -> None:
        """Charge one delivered revocation: the origin blocks for the ack,
        the recipient daemon pays the upcall (see IOSim.record_coherence)."""
        sim = getattr(cache, "sim", None)
        if sim is not None:
            sim.record_coherence(
                recipient_node=cache.client_node,
                origin_process=(ctx.process if ctx is not None else None))

    def punch(self, cache, name: str, origin, now: float, ctx=None) -> None:
        """Punches are destructive and rare: EVERY policy propagates them
        eagerly (serving pages of a deleted object for a lease buys
        nothing), and the revocation is a real message — counted and
        costed per sharer, under timeout leases too (a lease protocol
        cannot deliver destructive revokes for free).  The puncher's own
        cache drops locally, free."""
        if origin is cache:
            cache.invalidate(name)
            return
        if cache._entries.get(name) is None and not cache.has_dentry(name):
            return                   # not a sharer: no message to deliver
        self.stats.invalidations_sent += 1
        self._deliver(cache, ctx)
        if cache.invalidate(name):
            self.stats.invalidations_applied += 1

    # ---- client-side validation (read path) ----
    def validate(self, cache, entry, obj, ctx, offset: int,
                 size: int) -> bool:
        """May the covering pages of ``[offset, offset+size)`` be served
        as a hit?  Returning False means the caller treats the access as
        a miss (the policy may have dropped pages)."""
        return True

    def validate_dentry(self, cache, path: str, meta, process: int) -> bool:
        return True

    # ---- fill bookkeeping (no traffic: tokens piggyback on the fetch) ----
    def note_fill(self, cache, entry, obj, lo: int, hi: int) -> None:
        pass


class BroadcastPolicy(CoherencePolicy):
    """Eager push invalidation, page-granular and cost-true.  A foreign
    write drops the pages it overlaps in every sharer's cache
    (last-writer-wins, pending dirty data included); sibling ranks of one
    open transaction only get trimmed to the ranges they own inside the
    written extent; punch drops everything everywhere.  Delivery is only
    attempted at caches that actually hold the object (the engine-side
    sharer map any real protocol keeps), and each delivered message
    charges real fabric time: the origin blocks for the ack
    (``coh_msg_time`` + round trip) and the recipient daemon pays the
    upcall — the cost that makes write-sharing storms hurt in *time*, not
    just in message counts."""

    kind = "broadcast"

    def remote_write(self, cache, name, epoch, origin, now, offset=0,
                     nbytes=None, ctx=None) -> None:
        if origin is cache:
            return
        entry = cache._entries.get(name)
        if entry is None:
            return                   # not a sharer: no message to deliver
        if not cache.conflicts(entry, offset, nbytes):
            return                   # extent locks don't conflict: nothing
            #                          to revoke, no message (Lustre-style)
        if _tx_sibling(entry, epoch):
            # coordinated sibling ranks of one open transaction: the trim
            # rides the transaction's own commit barrier — not a coherence
            # message (it fires at staging AND at the commit replay, so
            # counting it would double-book), and nobody blocks on it
            cache.trim_to_dirty(name, offset, nbytes)
            return
        self.stats.invalidations_sent += 1
        # NOTE a tx-staged foreign write revokes here AND at the commit
        # replay: staged records leak into the committed view as soon as
        # the auto-epoch watermark passes them, so skipping the staging-
        # time revocation opens a real stale window (the conformance
        # harness fails if this is "optimised" away)
        self._deliver(cache, ctx)
        if cache.invalidate(name, offset, nbytes):
            self.stats.invalidations_applied += 1


class TimeoutPolicy(CoherencePolicy):
    """dfuse-style lease + revalidation, page-granular.  No traffic on
    writes; a cached page is served until ``attr_timeout`` after its last
    validation, then revalidated against the engine-side sub-tokens of
    the cells it overlaps (one batched round trip per read covers every
    expired page).  Staleness served is bounded by the timeout per page:
    a lease is only (re)granted when the token proves no foreign write
    landed inside the page since."""

    kind = "timeout"

    def __init__(self, attr_timeout: float = 1.0,
                 dentry_timeout: float | None = None) -> None:
        super().__init__()
        self.attr_timeout = float(attr_timeout)
        self.dentry_timeout = (self.attr_timeout if dentry_timeout is None
                               else float(dentry_timeout))

    @staticmethod
    def _page_tokens(cache, obj, pages) -> dict[int, int]:
        """Extent tokens for a batch of pages — one layout/engine walk via
        :func:`extent_tokens`.  Simulated cost is unchanged (tokens travel
        in one response); this is host-side efficiency on the read path."""
        pg = cache.page_bytes
        pages = list(pages)
        toks = extent_tokens(obj, [(p * pg, (p + 1) * pg) for p in pages])
        return dict(zip(pages, toks))

    # ---- notifications: bookkeeping only, no invalidation, no traffic ----
    def remote_write(self, cache, name, epoch, origin, now, offset=0,
                     nbytes=None, ctx=None) -> None:
        entry = cache._entries.get(name)
        if entry is None:
            return
        pages = cache.pages_for(entry, offset, nbytes)
        if origin is cache:
            # our own flush landed: renew the remembered per-page versions
            # so expiry revalidation doesn't treat our own write as
            # foreign — but ONLY on pages with no foreign write pending.
            # Adopting the current token over a stale-marked page would
            # swallow the foreign bump and let revalidation renew the
            # lease forever, unbounding staleness.
            renew = [p for p in pages
                     if p in entry.lease and p not in entry.pstale]
            if renew:
                entry.pver.update(self._page_tokens(cache, entry.obj,
                                                    renew))
            return
        if _tx_sibling(entry, epoch):
            return
        # only the touched pages the cache actually holds something for go
        # stale — a page with no cached state can never be served stale,
        # and marking it anyway would grow pstale without bound as
        # foreign writers stream over the rest of a large file
        for p in pages:
            if cache.holds_page(entry, p):
                entry.pstale.setdefault(p, now)

    # punch: the costed eager revoke inherited from CoherencePolicy —
    # destructive ops take no lease, and the revocation message is real
    # traffic under timeout coherence too

    # ---- read-path validation ----
    def validate(self, cache, entry, obj, ctx, offset, size) -> bool:
        sim = obj.pool.sim
        now = sim.clock.now
        pg = cache.page_bytes
        pages = range(offset // pg, -(-(offset + size) // pg))
        expired: list[int] = []
        first_touch: list[int] = []
        stale = False
        stale_age = 0.0
        for p in pages:
            granted = entry.lease.get(p)
            if granted is None:      # first touch (write-created page)
                if p not in entry.pstale:
                    first_touch.append(p)
                else:
                    # never validated AND already foreign-stale: no lease
                    # was ever granted, so there is nothing to serve under
                    # — revalidate right now (the missing token always
                    # mismatches: drop, honest miss, last-writer-wins)
                    expired.append(p)
            elif now - granted < self.attr_timeout:
                if p in entry.pstale:
                    stale = True
                    stale_age = max(stale_age, now - entry.pstale[p])
            else:
                expired.append(p)
        if first_touch or expired:
            tokens = self._page_tokens(cache, obj, first_touch + expired)
            for p in first_touch:
                entry.lease[p] = now
                entry.pver[p] = tokens[p]
        if expired:
            # one batched token lookup revalidates every expired page of
            # the read range (the tokens travel in one response)
            eng = _primary_live_engine(obj)
            self.stats.revalidations += 1
            if eng is not None:
                sim.record_reval(client_node=cache.client_node,
                                 process=ctx.process, engine=eng)
            dropped = False
            for p in expired:
                if tokens[p] == entry.pver.get(p, -1):
                    entry.lease[p] = now
                    entry.pstale.pop(p, None)
                else:
                    dropped = True
                    cache.invalidate(entry.obj.name, p * pg, pg)
            if dropped:
                self.stats.reval_misses += 1
                return False
            self.stats.reval_hits += 1
        if stale:
            self.stats.stale_hits += 1
            self.stats.max_staleness_s = max(self.stats.max_staleness_s,
                                             stale_age)
        return True

    def validate_dentry(self, cache, path, meta, process) -> bool:
        if meta is None or meta.get("vobj") is None:
            return True                      # no token provider: no lease
        vobj = meta["vobj"]
        sim = vobj.pool.sim
        now = sim.clock.now
        if now - meta["validated_at"] < self.dentry_timeout:
            return True
        eng = _primary_live_engine(vobj)
        self.stats.dentry_revalidations += 1
        if eng is not None:
            sim.record_reval(client_node=cache.client_node, process=process,
                             engine=eng)
        # the token of the *parent directory* KV object: any entry
        # create/unlink in that directory moves it (conservatively dropping
        # sibling dentries too — the weak-consistency tradeoff dfuse makes)
        if object_token(vobj) == meta["vtok"]:
            meta["validated_at"] = now
            return True
        cache.drop_dentry(path)
        return False

    def note_fill(self, cache, entry, obj, lo, hi) -> None:
        # a fill fetched current bytes for [lo, hi); the extent tokens
        # piggyback for free.  Fully refetched pages get a fresh lease
        # (stale cleared: their bytes ARE current); a partially covered
        # tail page is only leased on true first touch — granting it a
        # page-wide lease would extend the serving window of older bytes
        # in the same page, and staleness would escape the timeout bound.
        now = obj.pool.sim.clock.now
        pg = cache.page_bytes
        grant = [p for p in range(lo // pg, -(-hi // pg))
                 if (p + 1) * pg <= hi
                 or (entry.lease.get(p) is None and p not in entry.pstale)]
        tokens = self._page_tokens(cache, obj, grant) if grant else {}
        for p in grant:
            entry.lease[p] = now
            entry.pver[p] = tokens[p]
            entry.pstale.pop(p, None)


#: Mount-option surface: policy name -> constructor kwargs accepted.
POLICY_KINDS = ("broadcast", "timeout", "off")


def normalize_coherence(spec) -> dict:
    """Normalise a coherence spec (None | str | dict) into a plain dict
    ``{"policy": ..., ...kwargs}``.  ``None`` means the default
    (broadcast, the pre-refactor behaviour)."""
    if spec is None:
        return {"policy": "broadcast"}
    if isinstance(spec, str):
        spec = {"policy": spec}
    out = dict(spec)
    policy = out.setdefault("policy", "broadcast")
    if policy not in POLICY_KINDS:
        raise ValueError(f"coherence policy {policy!r}; known: {POLICY_KINDS}")
    return out


def make_policy(spec) -> CoherencePolicy | None:
    """Build a fresh per-cache policy instance from a spec.  Returns None
    for ``off`` — the interface then attaches no cache at all (direct
    I/O)."""
    spec = normalize_coherence(spec)
    kind = spec["policy"]
    if kind == "off":
        return None
    if kind == "timeout":
        return TimeoutPolicy(
            attr_timeout=spec.get("attr_timeout", spec.get("timeout", 1.0)),
            dentry_timeout=spec.get("dentry_timeout"))
    return BroadcastPolicy()
