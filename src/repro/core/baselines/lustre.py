"""Lustre-like POSIX parallel filesystem baseline.

The paper's closing observation (claim C5) is that DAOS delivers *similar*
bandwidth for file-per-process and single-shared-file, "in stark contrast to
the performance standard parallel filesystems provide".  To make that
contrast visible we model the standard-filesystem behaviour DAOS escapes:

* a single metadata server (MDS) serialising opens/creates;
* OST extent locks managed by a distributed lock manager (DLM): in
  shared-file mode, writers' extents interleave across OST stripes, so each
  OST sees lock ping-pong whose cost grows with the number of writers
  sharing it (the classic IOR-hard collapse);
* per-OST streaming bandwidth comparable to the DAOS engines, so the *only*
  structural difference is POSIX consistency enforcement.

This is a closed-form model, not a byte store — it exists to draw the
comparison line in the IOR benchmark figures.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class LustreModel:
    n_oss: int = 8                  # object storage servers
    osts_per_oss: int = 2
    ost_write_bw: float = 13e9      # match DAOS engine media for fairness
    ost_read_bw: float = 40e9
    oss_nic_bw: float = 12.5e9
    client_nic_bw: float = 12.5e9
    mds_op_time: float = 120e-6     # single MDS, serialised creates/opens
    lock_rt: float = 180e-6         # DLM lock revoke/grant round trip
    stripe_count_shared: int = 16   # shared file striped across all OSTs

    @property
    def n_osts(self) -> int:
        return self.n_oss * self.osts_per_oss

    def _common_bw(self, n_client_nodes: int, direction: str) -> float:
        ost_bw = self.ost_write_bw if direction == "write" else self.ost_read_bw
        media = self.n_osts * ost_bw
        server_net = self.n_oss * self.oss_nic_bw
        client_net = n_client_nodes * self.client_nic_bw
        return min(media, server_net, client_net)

    def easy_bandwidth(self, n_client_nodes: int, ppn: int,
                       block_bytes: int, direction: str) -> float:
        """File-per-process: near-ideal (modulo MDS create storm)."""
        nprocs = n_client_nodes * ppn
        total = nprocs * block_bytes
        t_io = total / self._common_bw(n_client_nodes, direction)
        t_mds = nprocs * self.mds_op_time          # create/open serialised
        return total / (t_io + t_mds)

    def hard_bandwidth(self, n_client_nodes: int, ppn: int,
                       block_bytes: int, transfer_bytes: int,
                       direction: str) -> float:
        """Single shared file: DLM extent-lock ping-pong on every OST.

        With W writers interleaving extents over S stripes, a transfer on a
        stripe whose lock another client holds pays revoke+grant before its
        data moves — the stripe's writers effectively take turns.  The
        per-stripe duty cycle is
            k_lock = t_transfer / (t_transfer + p_conflict * (W/S) * lock_rt)
        which is the classic IOR-hard collapse (10-25% of FPP bandwidth)."""
        nprocs = n_client_nodes * ppn
        total = nprocs * block_bytes
        bw = self._common_bw(n_client_nodes, direction)
        if direction == "read":
            # read locks are shared: mild overhead only
            t_io = total / bw
            return total / (t_io + nprocs * self.mds_op_time * 0.1)
        writers_per_stripe = max(1.0, nprocs / self.stripe_count_shared)
        p_conflict = 1.0 - 1.0 / writers_per_stripe
        t_transfer = transfer_bytes / self.ost_write_bw
        k_lock = t_transfer / (t_transfer
                               + p_conflict * writers_per_stripe * self.lock_rt)
        return bw * k_lock if writers_per_stripe > 1 else bw
