"""Checkpoint traffic on the unified interface/cache pipeline.

Three guarantees pinned here:

* **equivalence** — with ``cache_mode="none"`` the refactored checkpoint
  path (AccessInterface/FileHandle, tx-aware handles) produces
  byte-identical per-engine flow accounting and phase times to the seed
  path that hand-assembled ``IOCtx`` literals;
* **atomicity under write-back** — the container's commit barrier flushes
  tx-staged dirty data before the manifest becomes visible, so a client
  crash never exposes a manifest whose leaves still sit in a client buffer
  (and an abort never leaks staged bytes);
* **coherence** — a restore after a foreign client rewrites the checkpoint
  sees the new bytes: the writer's flush broadcasts invalidations into
  every other client-node cache attached to the container.
"""
import numpy as np
import pytest

from repro.core import IOCtx
from repro.core.interfaces import DFS, make_interface
from repro.ckpt import Checkpointer, CheckpointError
from repro.ckpt import serializer as S


def make_tree(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": (rng.normal(size=(64, 128)) * scale).astype(np.float32),
            "b": (rng.normal(size=(128,)) * scale).astype(np.float32),
        },
        "opt": {"m": np.zeros((32, 64), np.float32),
                "count": np.asarray(3, np.int32)},
    }


# ---------------- seed-path reference (PR-1 behaviour, verbatim) ----------
def _seed_save(dfs, iface, oclass, layout, n_writers, base, step, tree):
    """The seed checkpoint write path: hand-assembled ctx per call."""
    cont = dfs.cont
    sdir = f"{base}/step_{step:08d}"
    try:
        dfs.mkdir(sdir)
    except Exception:
        pass
    leaves = S.flatten_tree(tree)
    entries = {}
    tx = cont.tx_begin()
    if layout == "shared":
        fname = f"{sdir}/checkpoint.bin"
        obj = dfs.create_file(fname, oclass=oclass,
                              ctx=iface.make_ctx(0, 0))
        offset = 0
        for path, leaf in leaves:
            raw, meta = S.leaf_to_bytes(leaf)
            csum = S.checksum_leaf(raw)
            for w, (lo, hi) in enumerate(S.shard_ranges(raw.size, n_writers)):
                tx.write_array(obj, offset + lo, raw[lo:hi],
                               ctx=iface.make_ctx(w % 8, w))
            entries[path] = {**meta, "csum": csum, "file": fname,
                             "offset": offset, "nbytes": int(raw.size)}
            offset += int(raw.size)
            offset = -(-offset // 128) * 128
    else:
        for path, leaf in leaves:
            raw, meta = S.leaf_to_bytes(leaf)
            csum = S.checksum_leaf(raw)
            shards = []
            for w, (lo, hi) in enumerate(S.shard_ranges(raw.size, n_writers)):
                fname = f"{sdir}{path}.shard{w}"
                obj = dfs.create_file(fname, oclass=oclass,
                                      ctx=iface.make_ctx(w % 8, w))
                tx.write_array(obj, 0, raw[lo:hi],
                               ctx=iface.make_ctx(w % 8, w))
                shards.append({"file": fname, "lo": lo, "hi": hi})
            entries[path] = {**meta, "csum": csum, "shards": shards,
                             "nbytes": int(raw.size)}
    # manifest meta mirrors the current schema (n_writers rides along for
    # elastic restore) so the flow comparison pins the *pipeline*, not the
    # manifest's size
    manifest = S.manifest_dumps(entries, {"step": step, "layout": layout,
                                          "oclass": oclass,
                                          "n_writers": n_writers,
                                          "tier": "hot"})
    mobj = cont.open_kv(f"manifest:{sdir}", oclass="RP_3GX")
    # manifests are native libdaos KV objects, reached directly rather than
    # through the data mount, so the metadata plane charges them at the
    # native async ctx whatever interface carried the leaves; a single
    # record is flow-identical batched or serial, so the serial put IS the
    # oracle
    tx.put_kv(mobj, "manifest", "json", manifest, ctx=IOCtx(sync=False))
    tx.commit()
    return entries


def _seed_restore(dfs, iface, entries):
    """Seed read path: every leaf read with ctx(0, 0)."""
    out = {}
    ctx = iface.make_ctx(0, 0)
    for path, entry in entries.items():
        hi = entry["nbytes"]
        if "file" in entry:
            obj = dfs.open_file(entry["file"], ctx=ctx)
            out[path] = obj.read(entry["offset"], hi, ctx=ctx)
        else:
            buf = np.zeros(hi, np.uint8)
            for sh in entry["shards"]:
                obj = dfs.open_file(sh["file"], ctx=ctx)
                buf[sh["lo"]: sh["hi"]] = obj.read(0, sh["hi"] - sh["lo"],
                                                   ctx=ctx)
            out[path] = buf
    return out


def _flow_sig(ph):
    return sorted((f.engine, f.direction, f.nbytes, f.nops, f.cell_bytes,
                   f.client_node, f.process, f.sync, f.via_fuse)
                  for f in ph.flows)


def _engine_dir_bytes(ph):
    out = {}
    for f in ph.flows:
        k = (f.engine, f.direction)
        out[k] = out.get(k, 0) + f.nbytes
    return out


# ---------------- uncached equivalence to the seed path -------------------
@pytest.mark.parametrize("layout", ["sharded", "shared"])
@pytest.mark.parametrize("iface_name", ["dfs", "posix"])
def test_uncached_save_flows_match_seed_path(make_world, iface_name, layout):
    tree = make_tree()

    def run_seed():
        pool, dfs = make_world(label="ck")
        iface = make_interface(iface_name, dfs)
        dfs.mkdir("/ckpt")
        with pool.sim.phase() as ph:
            entries = _seed_save(dfs, iface, dfs.default_oclass, layout, 4,
                                 "/ckpt", 3, tree)
        return pool, dfs, iface, entries, ph

    def run_new():
        pool, dfs = make_world(label="ck")
        ck = Checkpointer(dfs, interface=iface_name, layout=layout,
                          n_writers=4)
        with pool.sim.phase() as ph:
            man = ck.save(3, tree)
        return pool, ck, man, ph

    s_pool, s_dfs, s_iface, s_entries, s_ph = run_seed()
    n_pool, ck, man, n_ph = run_new()
    assert _flow_sig(s_ph) == _flow_sig(n_ph)
    assert s_ph.elapsed == n_ph.elapsed
    assert s_ph.md_ops == n_ph.md_ops

    # restore: reader placement is deliberately spread across the writers'
    # nodes now (seed read everything from node 0), so we compare the
    # placement-independent accounting — per-engine byte/op totals —
    # plus bit-exactness of the restored bytes.
    with s_pool.sim.phase() as s_rph:
        # seed restore started with the manifest KV read
        mobj = s_dfs.cont.open_kv("manifest:/ckpt/step_00000003",
                                  oclass="RP_3GX")
        man_seed = S.manifest_loads(bytes(mobj.get("manifest", "json")))
        seed_items = _seed_restore(s_dfs, s_iface, man_seed["leaves"])
    with n_pool.sim.phase() as n_rph:
        back = ck.restore(3, tree)
    assert _engine_dir_bytes(s_rph) == _engine_dir_bytes(n_rph)
    raw_w = np.ascontiguousarray(tree["params"]["w"]).view(np.uint8)
    np.testing.assert_array_equal(seed_items["/params/w"],
                                  raw_w.reshape(-1))
    np.testing.assert_array_equal(back["params"]["w"], tree["params"]["w"])


# ---------------- cached save/restore stays bit-exact ---------------------
@pytest.mark.parametrize("layout", ["sharded", "shared"])
@pytest.mark.parametrize("iface_name",
                         ["posix-cached", "posix-readahead", "dfs-cached"])
def test_cached_save_restore_bit_exact(make_world, iface_name, layout):
    pool, dfs = make_world(label="ck")
    ck = Checkpointer(dfs, interface=iface_name, layout=layout, n_writers=4)
    tree = make_tree(seed=11)
    ck.save(1, tree)
    back = ck.restore(1, tree)       # verify_on_restore checks checksums
    for (pa, a), (pb, b) in zip(S.flatten_tree(tree), S.flatten_tree(back)):
        assert pa == pb
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype
    # and a cache-less foreign client sees the same bytes (data actually
    # reached the engines, not just the writer's cache)
    ck2 = Checkpointer(dfs, interface="dfs", layout=layout, n_writers=4)
    back2 = ck2.restore(1, tree)
    np.testing.assert_array_equal(back2["params"]["w"], tree["params"]["w"])


def test_cached_restore_hits_page_cache(make_world):
    """Restore of a just-written checkpoint is served node-locally."""
    pool, dfs = make_world(label="ck")
    ck = Checkpointer(dfs, interface="posix-cached", layout="sharded",
                      n_writers=4)
    tree = make_tree(seed=2)
    ck.save(5, tree)
    before = ck.iface.cache_stats()
    back = ck.restore(5, tree)
    np.testing.assert_array_equal(back["params"]["w"], tree["params"]["w"])
    after = ck.iface.cache_stats()
    assert after["read_misses"] == before.get("read_misses", 0)
    assert after["read_hits"] > before.get("read_hits", 0)


# ---------------- torn-save protection under write-back -------------------
def test_commit_flushes_writeback_before_manifest_visible(make_world):
    """The naive ordering (manifest visible while leaves sit in a client
    buffer) must be torn; the real save path must not be."""
    pool, dfs = make_world(label="ck")
    ck = Checkpointer(dfs, interface="posix-cached", layout="sharded",
                      n_writers=4)
    tree = make_tree(seed=4)

    # --- naive ordering, by hand: stage leaves under a tx through the
    # write-back cache, publish the manifest, then bump the committed epoch
    # WITHOUT the flush barrier — and "crash" the client before the kernel
    # flusher ran (its caches vanish, detached from the container).
    sdir = ck._step_dir(1)
    ck.iface.mkdir(sdir)
    leaves = S.flatten_tree(tree)
    entries = {}
    tx = dfs.cont.tx_begin()
    ck._save_sharded(tx, sdir, leaves, entries)
    manifest = S.manifest_dumps(entries, {"step": 1, "layout": "sharded",
                                          "oclass": ck.oclass})
    tx.put_kv(ck._manifest_kv(sdir), "manifest", "json", manifest)
    assert sum(c.dirty_bytes() for c in ck.iface._caches.values()) > 0
    dfs.cont._committed = max(dfs.cont._committed, tx.epoch)  # naive commit
    for c in ck.iface._caches.values():
        dfs.cont.detach_cache(c)                              # client crash
    reader = Checkpointer(dfs, interface="posix", layout="sharded",
                          n_writers=4)
    with pytest.raises(CheckpointError):
        reader.restore(1, tree)       # manifest visible, leaves torn

    # --- the real path: commit barrier flushes before the epoch flips
    ck2 = Checkpointer(dfs, interface="posix-cached", layout="sharded",
                       n_writers=4, base="/ckpt2")
    ck2.save(2, tree)
    assert sum(c.dirty_bytes() for c in ck2.iface._caches.values()) == 0
    reader2 = Checkpointer(dfs, interface="posix", layout="sharded",
                           n_writers=4, base="/ckpt2")
    back = reader2.restore(2, tree)
    np.testing.assert_array_equal(back["params"]["w"], tree["params"]["w"])


def test_aborted_save_leaks_nothing_from_cache(make_world):
    """A crash mid-save aborts the tx: staged cache state is dropped, no
    flush ever lands those bytes, and the next save is unaffected."""
    pool, dfs = make_world(label="ck")
    ck = Checkpointer(dfs, interface="posix-cached", layout="sharded",
                      n_writers=4)
    tree = make_tree(seed=6)
    orig = Checkpointer._save_sharded

    def boom(self, tx, sdir, leaves, entries):
        orig(self, tx, sdir, leaves[: len(leaves) // 2], entries)
        raise RuntimeError("injected crash mid-save")

    Checkpointer._save_sharded = boom
    try:
        with pytest.raises(RuntimeError):
            ck.save(1, tree)
    finally:
        Checkpointer._save_sharded = orig
    assert sum(c.dirty_bytes() for c in ck.iface._caches.values()) == 0
    with pytest.raises(CheckpointError):
        ck.load_manifest(1)
    ck.save(2, tree)
    back = ck.restore(2, tree)
    np.testing.assert_array_equal(back["params"]["w"], tree["params"]["w"])


# ---------------- multi-client coherence ----------------------------------
def test_restore_after_foreign_write_sees_new_bytes(make_world):
    """Client A restores (warming its node caches); client B rewrites the
    same step; A's next restore must see B's bytes — the container
    broadcast invalidated A's cached pages on B's flush."""
    pool, dfs = make_world(label="ck")
    ck_a = Checkpointer(dfs, interface="posix-cached", layout="sharded",
                        n_writers=4)
    ck_b = Checkpointer(dfs, interface="posix-cached", layout="sharded",
                        n_writers=4)
    tree_a = make_tree(seed=1)
    tree_b = make_tree(seed=2, scale=3.0)
    ck_a.save(7, tree_a)
    warm = ck_a.restore(7, tree_a)                 # A's caches now hold 7
    np.testing.assert_array_equal(warm["params"]["w"], tree_a["params"]["w"])
    assert sum(c.cached_bytes() for c in ck_a.iface._caches.values()) > 0
    ck_b.save(7, tree_b)                           # foreign rewrite
    back = ck_a.restore(7, tree_a)                 # must NOT serve stale A
    np.testing.assert_array_equal(back["params"]["w"], tree_b["params"]["w"])
    st = ck_a.iface.cache_stats()
    assert st["invalidations"] > 0


def test_gc_through_cached_interface_drops_cached_state(make_world):
    """delete_step through a cached interface invalidates pages + dentries
    for the unlinked files on every client-node cache."""
    pool, dfs = make_world(label="ck")
    ck = Checkpointer(dfs, interface="posix-cached", layout="sharded",
                      n_writers=4)
    tree = make_tree(seed=8)
    ck.save(1, tree)
    ck.restore(1, tree)
    assert sum(c.cached_bytes() for c in ck.iface._caches.values()) > 0
    ck.delete_step(1)
    assert sum(c.cached_bytes() for c in ck.iface._caches.values()) == 0
    with pytest.raises(CheckpointError):
        ck.load_manifest(1)
