"""deepseek-7b [dense] — 30L d4096 32H MHA(kv=32) ff11008 V102400.

Plain llama architecture.  [arXiv:2401.02954; hf deepseek-ai/deepseek-llm-7b]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=102400,
    rope_theta=10000.0, mlp="swiglu",
)
