"""Quickstart: train a small LM end-to-end on the DAOS-model store.

Everything flows through the paper's substrate: training data is read from
object-store shards (prefetched, straggler-tolerant), checkpoints are saved
asynchronously under epoch transactions with a replicated object class, and
the interface (dfs / posix / hdf5 / daos-array) is a config knob.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_arch, smoke_variant
from repro.core import Pool, Topology, bandwidth
from repro.core.interfaces import DFS
from repro.ckpt import Checkpointer, CheckpointManager
from repro.data import ObjectStoreDataset, Prefetcher, synthetic_corpus, \
    write_corpus
from repro.models import init_model, param_count
from repro.train import make_train_step, opt_init


def main() -> None:
    # ---- storage cluster (8 servers x 2 engines, paper's testbed) ----
    pool = Pool(Topology())
    cont = pool.create_container("quickstart", oclass="S2")
    dfs = DFS(cont)

    # ---- corpus -> object store ----
    corpus = synthetic_corpus(400_000, vocab=256, seed=0)
    n_shards = write_corpus(dfs, corpus, shard_tokens=32768,
                            interface="dfs", oclass="S2")
    print(f"corpus: {corpus.size:,} tokens in {n_shards} S2 objects")

    # ---- model (reduced deepseek-7b family) ----
    import dataclasses
    cfg = dataclasses.replace(smoke_variant(get_arch("deepseek-7b")),
                              vocab_size=256)
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = opt_init(cfg.optimizer, params)
    step = jax.jit(make_train_step(cfg))
    print(f"model: {param_count(params):,} params ({cfg.name} smoke)")

    # ---- checkpointing through the paper's interfaces ----
    ck = Checkpointer(dfs, interface="dfs", oclass="RP_2GX",
                      layout="sharded", n_writers=8)
    mgr = CheckpointManager(ck, save_every=20, keep_n=2)

    ds = ObjectStoreDataset(dfs)
    pf = Prefetcher(ds, depth=4)
    losses = []
    for i, batch in enumerate(pf.batches(batch=8, seq=64)):
        if i >= 60:
            break
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        mgr.maybe_save(i, {"params": params, "opt": opt})
        if i % 10 == 0:
            print(f"step {i:3d}  loss {losses[-1]:.4f}")
    mgr.drain()

    assert losses[-1] < losses[0] - 0.5, "model failed to learn"
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f}  "
          f"(sim storage time {pool.sim.clock.now * 1e3:.1f} ms)")

    # restore and verify bit-exactness
    stepno, tree = mgr.restore_latest({"params": params, "opt": opt})
    same = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(tree["params"]),
                               jax.tree.leaves(params))
               ) if stepno == 59 else True
    print(f"restored checkpoint from step {stepno} (verified checksums)")


if __name__ == "__main__":
    main()
