"""Model zoo: the 10 assigned architectures as pure-functional JAX."""
from .model import (cache_spec, forward_decode, forward_prefill,
                    forward_train, init_cache, init_model, input_specs,
                    make_inputs, param_count, param_shapes, text_len)

__all__ = ["cache_spec", "forward_decode", "forward_prefill",
           "forward_train", "init_cache", "init_model", "input_specs",
           "make_inputs", "param_count", "param_shapes", "text_len"]
