"""EventQueue (daos_eq_*) semantics.

The regression pinned here: ``poll()`` used to call ``e.test()`` twice per
event (once in the "done" comprehension, once in the "retained" one), so an
event completing *between* the two probes was dropped from both lists and
lost forever.  ``poll()`` must snapshot each event's completion exactly
once."""
import threading
import time
from concurrent.futures import TimeoutError as _FutTimeout

import pytest

from repro.core import EventQueue


class _RaceEvent:
    """Completion flips between the first and second ``test()`` probe —
    the exact interleaving that lost events."""

    def __init__(self) -> None:
        self.calls = 0

    def test(self) -> bool:
        self.calls += 1
        return self.calls >= 2


def test_poll_snapshots_test_once_event_never_lost():
    eq = EventQueue(depth=1)
    try:
        ev = _RaceEvent()
        eq._inflight.append(ev)
        first = eq.poll()
        # one probe only: the event read as pending and must be retained
        assert ev.calls == 1
        assert first == [] and eq.inflight == 1
        second = eq.poll()
        assert second == [ev] and eq.inflight == 0
    finally:
        eq._inflight.clear()
        eq.close()


def test_poll_returns_and_retires_completed_events():
    gate = threading.Event()
    with EventQueue(depth=2) as eq:
        fast = eq.submit(lambda: 42)
        slow = eq.submit(gate.wait, 5.0)
        fast.wait()
        done = eq.poll()
        assert fast in done and slow not in done
        assert eq.inflight == 1
        gate.set()
        slow.wait()
        # a completed event is returned by exactly one poll
        for _ in range(50):
            done2 = eq.poll()
            if done2:
                break
            time.sleep(0.01)
        assert done2 == [slow]
        assert eq.poll() == [] and eq.inflight == 0


def test_drain_reraises_first_error():
    def boom():
        raise RuntimeError("injected")

    eq = EventQueue(depth=1)
    eq.submit(boom)
    try:
        eq.drain()
    except RuntimeError as e:
        assert "injected" in str(e)
    else:  # pragma: no cover
        raise AssertionError("drain() swallowed the error")
    finally:
        eq.close()


def test_submit_backpressure_blocks_at_depth():
    """depth is a real bound: the (depth+1)-th submit blocks until a slot
    frees — the queue itself is the backpressure, not an unbounded list."""
    gate = threading.Event()
    entered = threading.Event()
    with EventQueue(depth=2) as eq:
        eq.submit(gate.wait, 5.0)
        eq.submit(gate.wait, 5.0)
        third_in = threading.Event()

        def oversubmit():
            entered.set()
            eq.submit(lambda: 3)
            third_in.set()

        t = threading.Thread(target=oversubmit, daemon=True)
        t.start()
        entered.wait(1.0)
        assert not third_in.wait(0.1)       # blocked: queue is full
        assert eq.inflight == 2
        gate.set()                          # a slot frees...
        assert third_in.wait(2.0)           # ...and the submit goes through
        t.join(2.0)


def test_backpressure_never_loses_forced_out_errors():
    """An event force-retired by a full-queue submit keeps its error: it
    re-raises at the next drain instead of vanishing."""
    def boom():
        raise RuntimeError("forced out")

    eq = EventQueue(depth=1)
    try:
        eq.submit(boom)
        ok = eq.submit(lambda: 1)           # forces boom's retirement
        assert ok.wait() == 1
        with pytest.raises(RuntimeError, match="forced out"):
            eq.drain()
        eq.drain()                          # raised exactly once
    finally:
        eq.close()


# ------------------------------------------------ completion callbacks --
def test_on_complete_fires_exactly_once_with_the_event():
    seen = []
    with EventQueue(depth=2) as eq:
        ev = eq.submit(lambda: 7, on_complete=seen.append)
        assert ev.wait() == 7
        for _ in range(100):                # callback runs on the worker
            if seen:
                break
            time.sleep(0.01)
    assert seen == [ev]


def test_on_complete_on_already_done_event_fires_inline():
    with EventQueue(depth=1) as eq:
        ev = eq.submit(lambda: 1)
        ev.wait()
        seen = []
        assert ev.on_complete(seen.append) is ev
        # already complete: the callback ran right here, synchronously
        assert seen == [ev]


def test_on_complete_chains_submissions_without_deadlock():
    """The checkpointer's overlap pattern: each completion callback
    submits the next stage from a *worker* thread.  Submitting from a
    callback must not deadlock the queue, and the chain must execute in
    order."""
    order = []
    events = {}
    lock = threading.Lock()
    with EventQueue(depth=2) as eq:
        def work(i):
            with lock:
                order.append(i)
            return i

        def chain(i):
            def _cb(_ev):
                if i + 1 < 5:
                    events[i + 1] = eq.submit(work, i + 1,
                                              on_complete=chain(i + 1))
            return _cb

        events[0] = eq.submit(work, 0, on_complete=chain(0))
        deadline = time.monotonic() + 5.0
        while len(events) < 5 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert sorted(events) == list(range(5))
        assert [events[i].wait() for i in range(5)] == list(range(5))
    assert order == list(range(5))


def test_chained_stage_is_in_flight_before_the_consumer_asks():
    """Overlap, observed: once stage N completes, its callback has
    already submitted stage N+1 — the consumer finds it in flight
    without having requested it (shard N+1 serialises while shard N
    flushes)."""
    gate = threading.Event()
    nxt = {}
    with EventQueue(depth=2) as eq:
        ev0 = eq.submit(lambda: 0,
                        on_complete=lambda _e: nxt.setdefault(
                            1, eq.submit(gate.wait, 5.0)))
        assert ev0.wait() == 0
        deadline = time.monotonic() + 2.0
        while 1 not in nxt and time.monotonic() < deadline:
            time.sleep(0.01)
        assert 1 in nxt                     # submitted by the callback
        gate.set()
        nxt[1].wait()


def test_on_complete_fires_on_error_and_wait_still_raises():
    def boom():
        raise RuntimeError("injected")

    seen = []
    eq = EventQueue(depth=1)
    try:
        ev = eq.submit(boom, on_complete=lambda e: seen.append(e.error))
        with pytest.raises(RuntimeError, match="injected"):
            ev.wait()
        for _ in range(100):
            if seen:
                break
            time.sleep(0.01)
        assert isinstance(seen[0], RuntimeError)
        with pytest.raises(RuntimeError, match="injected"):
            eq.drain()                      # the error still surfaces
    finally:
        eq.close()


def test_on_complete_exception_does_not_poison_the_event():
    with EventQueue(depth=1) as eq:
        ev = eq.submit(lambda: 5, on_complete=lambda e: 1 / 0)
        assert ev.wait() == 5               # callback errors are swallowed
        assert ev.error is None


def test_drain_timeout_is_a_deadline_not_per_event():
    """Draining several slow events must time out after ~timeout total,
    not timeout-per-event."""
    gate = threading.Event()
    eq = EventQueue(depth=4)
    try:
        for _ in range(4):
            eq.submit(gate.wait, 10.0)
        t0 = time.monotonic()
        with pytest.raises(Exception) as ei:
            eq.drain(timeout=0.2)
        took = time.monotonic() - t0
        assert isinstance(ei.value, (TimeoutError, _FutTimeout))
        assert took < 1.0                   # one deadline, not 4 x 0.2
    finally:
        gate.set()
        eq.close()
