"""Data pipeline (prefetch, stragglers, loss tolerance) + failure detection
+ elastic replanning + hlo cost analyzer."""
import numpy as np
import pytest

from repro.core import Pool, Topology
from repro.core.interfaces import DFS
from repro.data import (ObjectStoreDataset, Prefetcher, synthetic_corpus,
                        write_corpus)
from repro.ft import FailureDetector, replan_data_parallel


@pytest.fixture()
def world():
    pool = Pool(Topology(n_server_nodes=4, engines_per_node=2))
    cont = pool.create_container("d", oclass="S2")
    dfs = DFS(cont)
    return pool, dfs


def test_corpus_roundtrip(world):
    pool, dfs = world
    corpus = synthetic_corpus(10_000, 256, seed=1)
    n = write_corpus(dfs, corpus, shard_tokens=1024)
    assert n == 10
    ds = ObjectStoreDataset(dfs)
    got = np.concatenate([ds.read_shard(i) for i in range(len(ds))])
    np.testing.assert_array_equal(got, corpus)


def test_prefetcher_order_and_batches(world):
    pool, dfs = world
    corpus = synthetic_corpus(20_000, 256, seed=2)
    write_corpus(dfs, corpus, shard_tokens=2048)
    ds = ObjectStoreDataset(dfs)
    pf = Prefetcher(ds, depth=3)
    batches = list(pf.batches(batch=4, seq=128))
    assert len(batches) >= 30
    assert batches[0]["tokens"].shape == (4, 128)
    # tokens come from the corpus in order
    np.testing.assert_array_equal(batches[0]["tokens"].reshape(-1),
                                  corpus[: 4 * 128])


def test_prefetcher_tolerates_lost_shards(world):
    pool, dfs = world
    corpus = synthetic_corpus(20_000, 256, seed=3)
    write_corpus(dfs, corpus, shard_tokens=2048)  # S2: unprotected
    ds = ObjectStoreDataset(dfs)
    pool.fail_engine(0)
    pool.fail_engine(1)
    pf = Prefetcher(ds, depth=2)
    got = 0
    while True:
        try:
            pf.get()
            got += 1
        except StopIteration:
            break
    assert got + len(pf.failed) == len(ds)
    assert got > 0  # pipeline made progress despite dead engines


def test_failure_detector_and_replan(world):
    pool, _ = world
    det = FailureDetector(pool, n_workers=8)
    assert det.poll(0) == []
    pool.fail_engine(3)
    det.fail_worker(7, step=5)
    events = det.poll(5)
    kinds = {(e.kind, e.ident) for e in events}
    assert ("engine", 3) in kinds and ("worker", 7) in kinds
    assert det.n_alive_workers == 7
    dp, per = replan_data_parallel(256, det.n_alive_workers)
    assert dp <= 7 and 256 % dp == 0 and dp * per == 256
    assert replan_data_parallel(256, 8) == (8, 32)


def test_hlo_cost_scan_multiplier():
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_cost import analyze

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    r = analyze(c.as_text())
    assert r["flops"] == 8 * 2 * 128 * 256 * 256
    assert r["hbm_bytes"] > 0
    # unscaled XLA report counts the body once: must be 8x smaller
    cost = c.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    assert float(cost["flops"]) * 8 == r["flops"]
