"""Replication and erasure-coding helpers (RP_k / EC_kP1).

DAOS protects objects either by full replication (RP_*) or Reed-Solomon
erasure coding (EC_kPp).  We implement XOR parity (p=1) — sufficient to
demonstrate degraded reads and rebuild, and byte-exact testable.
"""
from __future__ import annotations

import numpy as np


def xor_parity(cells: list[bytes], cell_size: int) -> bytes:
    """Parity cell = XOR of data cells, each zero-padded to cell_size."""
    acc = np.zeros(cell_size, np.uint8)
    for c in cells:
        a = np.frombuffer(c, np.uint8)
        if a.size < cell_size:
            a = np.concatenate([a, np.zeros(cell_size - a.size, np.uint8)])
        elif a.size > cell_size:
            raise ValueError("cell larger than cell_size")
        acc ^= a
    return acc.tobytes()


def reconstruct(surviving: list[bytes], parity: bytes, cell_size: int,
                lost_length: int) -> bytes:
    """Recover the single lost data cell from the k-1 survivors + parity."""
    acc = np.frombuffer(xor_parity(surviving, cell_size), np.uint8).copy()
    p = np.frombuffer(parity, np.uint8)
    if p.size < cell_size:
        p = np.concatenate([p, np.zeros(cell_size - p.size, np.uint8)])
    acc ^= p
    return acc[:lost_length].tobytes()


class DataLossError(IOError):
    """Unprotected data lived only on a failed engine."""
