"""EventQueue (daos_eq_*) semantics.

The regression pinned here: ``poll()`` used to call ``e.test()`` twice per
event (once in the "done" comprehension, once in the "retained" one), so an
event completing *between* the two probes was dropped from both lists and
lost forever.  ``poll()`` must snapshot each event's completion exactly
once."""
import threading
import time

from repro.core import EventQueue


class _RaceEvent:
    """Completion flips between the first and second ``test()`` probe —
    the exact interleaving that lost events."""

    def __init__(self) -> None:
        self.calls = 0

    def test(self) -> bool:
        self.calls += 1
        return self.calls >= 2


def test_poll_snapshots_test_once_event_never_lost():
    eq = EventQueue(depth=1)
    try:
        ev = _RaceEvent()
        eq._inflight.append(ev)
        first = eq.poll()
        # one probe only: the event read as pending and must be retained
        assert ev.calls == 1
        assert first == [] and eq.inflight == 1
        second = eq.poll()
        assert second == [ev] and eq.inflight == 0
    finally:
        eq._inflight.clear()
        eq.close()


def test_poll_returns_and_retires_completed_events():
    gate = threading.Event()
    with EventQueue(depth=2) as eq:
        fast = eq.submit(lambda: 42)
        slow = eq.submit(gate.wait, 5.0)
        fast.wait()
        done = eq.poll()
        assert fast in done and slow not in done
        assert eq.inflight == 1
        gate.set()
        slow.wait()
        # a completed event is returned by exactly one poll
        for _ in range(50):
            done2 = eq.poll()
            if done2:
                break
            time.sleep(0.01)
        assert done2 == [slow]
        assert eq.poll() == [] and eq.inflight == 0


def test_drain_reraises_first_error():
    def boom():
        raise RuntimeError("injected")

    eq = EventQueue(depth=1)
    eq.submit(boom)
    try:
        eq.drain()
    except RuntimeError as e:
        assert "injected" in str(e)
    else:  # pragma: no cover
        raise AssertionError("drain() swallowed the error")
    finally:
        eq.close()
