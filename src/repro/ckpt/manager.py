"""Checkpoint lifecycle: rolling saves, latest-restore, failure recovery.

The manager is the piece the 1000-node story leans on:
  * saves every `save_every` steps, asynchronously, keeping `keep_n`;
  * on restore it walks back from the newest manifest until one passes
    checksum verification (a half-dead engine can't brick training);
  * if the pool lost engines, it triggers rebuild() before reading;
  * elastic: `restore(..., template)` reads whatever shard ranges the new
    topology needs (see Checkpointer.restore_slice).
"""
from __future__ import annotations

from ..core import DataLossError, EngineFailedError
from .checkpointer import Checkpointer, CheckpointError


class CheckpointManager:
    def __init__(self, ckpt: Checkpointer, save_every: int = 100,
                 keep_n: int = 3,
                 demote_old: bool | None = None) -> None:
        self.ckpt = ckpt
        self.save_every = save_every
        self.keep_n = keep_n
        # keep_n demotion: on a tiered mount, GC *demotes* expired steps
        # to the cold tier (still restorable — an elastic restart reaching
        # past the hot window promotes them back) instead of deleting.
        # None = autodetect from the mount; asking for it without a cold
        # tier is an error, not a silent fallback to delete.
        tiered = getattr(ckpt.iface, "tier_aware", False)
        if demote_old and not tiered:
            raise CheckpointError(
                "demote_old requires a tiered:// checkpoint mount: "
                f"{type(ckpt.iface).__name__} has no cold tier")
        self.demote_old = tiered if demote_old is None else bool(demote_old)
        self.saved_steps: list[int] = []
        self.demoted_steps: list[int] = []
        self._pending: list = []

    # ------------- save path -------------
    def maybe_save(self, step: int, tree, extra_meta=None,
                   async_: bool = True) -> bool:
        if step % self.save_every:
            return False
        if async_:
            ev = self.ckpt.async_save(step, tree, extra_meta)
            self._pending.append((step, ev))
        else:
            self.ckpt.save(step, tree, extra_meta)
        self.saved_steps.append(step)
        self._gc()
        return True

    def _gc(self) -> None:
        while len(self.saved_steps) > self.keep_n:
            old = self.saved_steps.pop(0)
            try:
                if self.demote_old:
                    # keep_n bounds the HOT tier: expired steps spill to
                    # cold capacity, still restorable for elastic restarts
                    # reaching past the hot window
                    self.ckpt.drain()   # the step's save must be durable
                    self.ckpt.demote_step(old)
                    self.demoted_steps.append(old)
                else:
                    # full reclamation: shard files, manifest KV object and
                    # the step directory entry — keep_n bounds store use
                    self.ckpt.delete_step(old)
            except Exception:
                pass  # gc is best-effort

    def drain(self) -> None:
        self.ckpt.drain()
        self._pending.clear()

    # ------------- restore path -------------
    def restore_latest(self, template, pool=None):
        """-> (step, tree) from the newest restorable checkpoint."""
        try:
            self.drain()
        except Exception:
            # an async save racing the failure may itself have died — that
            # epoch never committed, so it simply doesn't exist.
            self._pending.clear()
        candidates = sorted(set(self.saved_steps) | set(self.demoted_steps),
                            reverse=True) or self._discover_steps()
        last_err: Exception | None = None
        for step in candidates:
            try:
                return step, self.ckpt.restore(step, template)
            except (CheckpointError, EngineFailedError, DataLossError,
                    KeyError) as e:
                last_err = e
                if pool is not None:
                    # degraded read failed: restore redundancy, retry once
                    pool.rebuild()
                    try:
                        return step, self.ckpt.restore(step, template)
                    except Exception as e2:  # walk back to older step
                        last_err = e2
        raise CheckpointError(
            f"no restorable checkpoint found: {last_err}")

    def _discover_steps(self) -> list[int]:
        return self.ckpt.list_steps()
