"""Epoch-based transactions (daos_tx_*).

A transaction allocates an epoch above the committed watermark; writes made
under it are stored versioned-at-epoch but invisible to readers (whose
snapshot is the committed epoch) until commit.  Abort punches the staged
epoch.  This is what makes checkpoints atomic: a writer that dies mid-save
leaves only invisible garbage, never a torn checkpoint.
"""
from __future__ import annotations


class TxStateError(RuntimeError):
    pass


class Transaction:
    def __init__(self, container) -> None:
        self.container = container
        self.epoch = container.alloc_epoch()
        self.state = "open"            # open | committed | aborted
        self.touched_engines: set[int] = set()
        # (name, offset, nbytes, ctx) per array write staged under this
        # epoch: the commit replays these as coherence notifications —
        # staged data only *changes* what readers see at commit, so that
        # is when foreign caches must drop/destale the extents (the
        # staging-time notification they also get can only make them
        # refetch still-current pre-commit bytes)
        self.write_log: list[tuple] = []
        # submission queues of handles opened under this tx: the commit
        # barrier drains them (queued IODs must hit the engines before the
        # epoch turns visible); an abort discards their unexecuted ops
        self.subqueues: list = []

    # -- write-side helpers (objects call these through the handle) ----------
    def touch(self, engine_id: int) -> None:
        self.touched_engines.add(engine_id)

    def register_subq(self, sq) -> None:
        """Attach a handle's submission queue to this tx's barriers."""
        if sq not in self.subqueues:
            self.subqueues.append(sq)

    def write_array(self, obj, offset: int, data, ctx=None) -> int:
        self._check_open()
        lay = obj._layout()
        for t in lay.targets:
            self.touch(t)
        kw = {"ctx": ctx} if ctx is not None else {}
        n = obj.write(offset, data, epoch=self.epoch, **kw)
        self.write_log.append((obj.name, offset, n, ctx))
        return n

    def write_sized(self, obj, offset: int, nbytes: int, ctx=None) -> int:
        """Sized (synthetic-payload) write staged under this tx's epoch."""
        self._check_open()
        lay = obj._layout()
        for t in lay.targets:
            self.touch(t)
        kw = {"ctx": ctx} if ctx is not None else {}
        obj.write_sized(offset, nbytes, epoch=self.epoch, **kw)
        self.write_log.append((obj.name, offset, nbytes, ctx))
        return nbytes

    def kv_batch(self, obj, ctx=None, qd=None):
        """Open a pipelined KV window staged under this tx's epoch.

        The batch registers itself in ``subqueues``: the commit barrier
        drains it exactly as it drains extent submission queues, and abort
        discards its unexecuted tail."""
        from .object import DEFAULT_CTX, KVBatch
        self._check_open()
        return KVBatch(obj, ctx=DEFAULT_CTX if ctx is None else ctx,
                       tx=self, qd=qd)

    def put_kv(self, obj, dkey, akey, value, ctx=None) -> None:
        self._check_open()
        for eid in obj._replicas_for(dkey):
            self.touch(eid)
        kw = {"ctx": ctx} if ctx is not None else {}
        obj.put(dkey, akey, value, epoch=self.epoch, **kw)

    def read_array(self, obj, offset: int, size: int, ctx=None):
        """Reads inside the tx see the tx's own writes."""
        kw = {"ctx": ctx} if ctx is not None else {}
        return obj.read(offset, size, epoch=float(self.epoch), **kw)

    def read_sized(self, obj, offset: int, nbytes: int, ctx=None) -> int:
        kw = {"ctx": ctx} if ctx is not None else {}
        return obj.read_sized(offset, nbytes, epoch=float(self.epoch), **kw)

    # -- lifecycle ------------------------------------------------------------
    def _check_open(self) -> None:
        if self.state != "open":
            raise TxStateError(f"transaction is {self.state}")

    def commit(self) -> None:
        self._check_open()
        self.container.commit_tx(self)
        self.state = "committed"

    def abort(self) -> int:
        self._check_open()
        n = self.container.abort_tx(self)
        self.state = "aborted"
        return n

    def __enter__(self) -> "Transaction":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self.state == "open":
            if exc_type is None:
                self.commit()
            else:
                self.abort()
