"""The pluggable cache-coherence layer (core/coherence.py).

Pinned here:

* mount-option parsing (``posix-cached:timeout=1.0`` style) selects and
  parameterises the policy;
* ``off`` is byte-for-byte the uncached interface (identical flows and
  phase times — direct I/O, no cache object at all);
* ``broadcast`` is flow-equivalent to the default (it *is* the default:
  the pre-refactor scheme extracted into a policy);
* ``timeout`` serves bounded-stale data during the lease, then
  revalidates against the engine-side version token — a cheap op, not a
  re-fetch — with staleness never exceeding the timeout;
* transaction semantics (commit barrier, abort drop) hold under every
  policy.
"""
import numpy as np
import pytest

from repro.core import Pool, Topology
from repro.core.coherence import (BroadcastPolicy, TimeoutPolicy,
                                  make_policy, normalize_coherence,
                                  object_token)
from repro.core.interfaces import DFS, make_interface, parse_mount_options


@pytest.fixture()
def world():
    pool = Pool(Topology(), materialize=True)
    cont = pool.create_container("c", oclass="S2")
    dfs = DFS(cont)
    dfs.mkdir("/d")
    return pool, dfs


# ---------------- mount options / policy construction ----------------
def test_mount_option_parsing(world):
    pool, dfs = world
    kw = parse_mount_options("timeout=0.5,readahead=4,wb_mib=8")
    assert kw["coherence"] == {"policy": "timeout", "attr_timeout": 0.5,
                               "dentry_timeout": 0.5}
    assert kw["cache_opts"] == {"readahead_pages": 4,
                                "wb_buffer_bytes": 8 << 20}
    iface = make_interface("posix-cached:timeout=0.5,readahead=4", dfs)
    cache = iface.cache_for(0)
    assert isinstance(cache.policy, TimeoutPolicy)
    assert cache.policy.attr_timeout == 0.5
    assert cache.readahead_pages == 4
    with pytest.raises(ValueError):
        parse_mount_options("bogus_knob=1")
    with pytest.raises(ValueError):
        make_interface("posix-cached:coherence=bogus", dfs)
    with pytest.raises(KeyError):
        make_interface("not-an-interface:timeout=1", dfs)


def test_policy_factory():
    assert isinstance(make_policy(None), BroadcastPolicy)
    assert isinstance(make_policy("broadcast"), BroadcastPolicy)
    assert make_policy("off") is None
    p = make_policy({"policy": "timeout", "attr_timeout": 2.0})
    assert isinstance(p, TimeoutPolicy) and p.attr_timeout == 2.0
    assert p.dentry_timeout == 2.0          # defaults to attr_timeout
    assert normalize_coherence(None) == {"policy": "broadcast"}


# ---------------- off == uncached, byte for byte ----------------
def test_off_matches_uncached_byte_for_byte():
    def run(name):
        pool = Pool(Topology(n_client_nodes=2), materialize=True)
        cont = pool.create_container("c", oclass="S2")
        dfs = DFS(cont)
        dfs.mkdir("/d")
        iface = make_interface(name, dfs)
        payload = (np.arange(256 << 10) % 251).astype(np.uint8)
        with pool.sim.phase() as wph:
            h = iface.create("/d/f", client_node=0, process=0)
            h.write_at(0, payload)
            h.fsync()
        with pool.sim.phase() as rph:
            h2 = iface.open("/d/f", client_node=1, process=9)
            got = h2.read_at(0, payload.size)
        sig = lambda ph: sorted(  # noqa: E731
            (f.engine, f.direction, f.nbytes, f.nops, f.client_node,
             f.process, f.sync, f.via_fuse) for f in ph.flows)
        return (sig(wph), sig(rph), wph.elapsed, rph.elapsed, bytes(got),
                iface)

    base = run("posix")
    off = run("posix-cached:coherence=off")
    assert base[:5] == off[:5]
    assert off[5]._caches == {}              # no cache was ever created
    assert off[5].cache_mode == "none"


# ---------------- broadcast is the (extracted) default ----------------
def test_broadcast_explicit_equals_default(world):
    pool, dfs = world
    for name in ("posix-cached", "posix-cached:coherence=broadcast"):
        iface = make_interface(name, dfs)
        assert isinstance(iface.cache_for(0).policy, BroadcastPolicy)


def test_broadcast_counts_storm_messages(world):
    """One foreign flush delivers one message to every non-origin cache —
    the write-sharing storm the coherence study quantifies."""
    pool, dfs = world
    iface = make_interface("posix-cached", dfs)
    handles = [iface.create("/d/s", client_node=0, process=0)]
    for node in range(1, 4):
        handles.append(iface.dup(handles[0], client_node=node, process=node))
    for h in handles:                        # warm all four node caches
        h.write_at(0, b"x" * 64)
        h.fsync()
    sent_before = iface.coherence_stats()["invalidations_sent"]
    handles[0].write_at(0, b"y" * 64)
    handles[0].fsync()
    st = iface.coherence_stats()
    assert st["policy"] == "broadcast"
    assert st["invalidations_sent"] - sent_before == 3   # all but origin
    # timeout policy: the same event produces zero messages
    iface_t = make_interface("posix-cached:timeout=1.0", dfs)
    ht = [iface_t.create("/d/t", client_node=0, process=0)]
    for node in range(1, 4):
        ht.append(iface_t.dup(ht[0], client_node=node, process=node))
    for h in ht:
        h.write_at(0, b"x" * 64)
        h.fsync()
    assert iface_t.coherence_stats()["messages"] == 0


# ---------------- timeout: bounded staleness + revalidation ----------------
def test_timeout_serves_stale_then_revalidates(world):
    pool, dfs = world
    iface = make_interface("posix-cached:timeout=0.5", dfs)
    h0 = iface.create("/d/f", client_node=0, process=0)
    h0.write_at(0, b"old-old-old")
    h0.fsync()
    assert bytes(h0.read_at(0, 11)) == b"old-old-old"    # own data, cached
    h1 = iface.dup(h0, client_node=1, process=9)
    h1.write_at(0, b"new-new-new")
    h1.fsync()                                           # foreign write
    # within the lease: node 0 serves its stale pages, no coherence traffic
    assert bytes(h0.read_at(0, 11)) == b"old-old-old"
    p0 = iface.cache_for(0).policy
    assert p0.stats.stale_hits >= 1
    assert p0.stats.revalidations == 0
    assert iface.cache_for(0).stats.invalidations == 0
    # lease expires: revalidation sees the token moved and drops the entry
    pool.sim.clock.advance(0.6)
    with pool.sim.phase() as ph:
        got = h0.read_at(0, 11)
    assert bytes(got) == b"new-new-new"
    assert p0.stats.revalidations == 1 and p0.stats.reval_misses == 1
    assert len(ph.reval_flows) == 1          # the token round trip is charged


def test_timeout_reval_hit_renews_lease_without_refetch(world):
    pool, dfs = world
    iface = make_interface("posix-cached:timeout=0.5", dfs)
    h = iface.create("/d/q", client_node=0, process=0)
    h.write_at(0, b"stable-data")
    h.fsync()
    assert bytes(h.read_at(0, 11)) == b"stable-data"
    misses_before = iface.cache_stats()["read_misses"]
    pool.sim.clock.advance(1.0)              # expire the lease; no writer
    with pool.sim.phase() as ph:
        assert bytes(h.read_at(0, 11)) == b"stable-data"
    p = iface.cache_for(0).policy
    assert p.stats.revalidations == 1 and p.stats.reval_hits == 1
    assert iface.cache_stats()["read_misses"] == misses_before  # no re-fetch
    assert len(ph.reval_flows) == 1


def test_staleness_bounded_by_timeout(world):
    pool, dfs = world
    tau = 0.5
    iface = make_interface(f"posix-cached:timeout={tau}", dfs)
    h0 = iface.create("/d/b", client_node=0, process=0)
    h1 = iface.dup(h0, client_node=1, process=9)
    rng = np.random.default_rng(3)
    for i in range(12):
        h1.write_at(0, bytes([i % 251]) * 64)
        h1.fsync()
        pool.sim.clock.advance(float(rng.uniform(0.05, 0.3)))
        h0.read_at(0, 64)
        pool.sim.clock.advance(float(rng.uniform(0.05, 0.3)))
    st = iface.cache_for(0).policy.stats
    assert st.max_staleness_s <= tau + 1e-9


def test_timeout_revalidation_is_cheaper_than_refetch(world):
    """The reval op must cost less simulated time than re-fetching the
    readahead window it saves."""
    pool, dfs = world
    iface = make_interface("posix-cached:timeout=0.25", dfs)
    h = iface.create("/d/r", client_node=0, process=0)
    h.write_at(0, np.zeros(4 << 20, np.uint8))
    h.fsync()
    h.read_at(0, 1 << 20)
    pool.sim.clock.advance(1.0)
    with pool.sim.phase() as reval_ph:       # lease expired, token unmoved
        h.read_at(0, 1 << 20)
    iface.cache_for(0).invalidate(h.obj.name)
    with pool.sim.phase() as fetch_ph:       # cold re-fetch for contrast
        h.read_at(0, 1 << 20)
    setup = pool.sim.hw.setup_time           # per-phase constant, not I/O
    assert reval_ph.elapsed - setup < (fetch_ph.elapsed - setup) / 5


def test_timeout_dentry_lease_and_revalidation(world):
    pool, dfs = world
    iface = make_interface("posix-cached:timeout=0.5", dfs)
    other = make_interface("dfs", dfs)
    iface.create("/d/k1", client_node=0, process=0).close()
    assert iface.stat("/d/k1")["type"] == "file"         # dentry cached
    p = iface.cache_for(0).policy
    # a foreign sibling create moves the parent-dir token ...
    other.create("/d/k2", client_node=1, process=9).close()
    # ... but within the lease the dentry is served without revalidation
    assert iface.stat("/d/k1")["type"] == "file"
    assert iface.cache_stats()["dentry_hits"] >= 1
    assert p.stats.dentry_revalidations == 0
    # lease expires: revalidation sees the parent token moved, drops the
    # dentry (conservative: sibling churn evicts too) and re-looks-up
    pool.sim.clock.advance(1.0)
    misses_before = iface.cache_stats()["dentry_misses"]
    assert iface.stat("/d/k1")["type"] == "file"         # still exists
    assert p.stats.dentry_revalidations >= 1
    assert iface.cache_stats()["dentry_misses"] > misses_before
    # unlink is destructive: the punch drops the dentry eagerly, no lease
    other.unlink("/d/k1")
    with pytest.raises(FileNotFoundError):
        iface.stat("/d/k1")


def test_own_flush_does_not_mask_pending_foreign_write(world):
    """Regression: node A caches [0,N); node B overwrites it; A then
    writes a *disjoint* range and flushes.  A's own-flush version renewal
    must NOT adopt the global token (which already covers B's write) —
    that would turn every later revalidation into a lease renewal and
    unbound the staleness."""
    pool, dfs = world
    tau = 1.0
    iface = make_interface(f"posix-cached:timeout={tau}", dfs)
    ha = iface.create("/d/mask", client_node=0, process=0)
    ha.write_at(0, b"A" * 64)
    ha.fsync()
    ha.read_at(0, 64)                        # A's cache holds [0,64)
    hb = iface.dup(ha, client_node=1, process=9)
    hb.write_at(0, b"B" * 64)
    hb.fsync()                               # foreign overwrite, A stale
    ha.write_at(1024, b"a" * 64)             # A writes a DISJOINT range
    ha.fsync()                               # ... own flush renews nothing
    pool.sim.clock.advance(10 * tau)         # far past any lease
    got = bytes(ha.read_at(0, 64))
    assert got == b"B" * 64                  # revalidation caught B's write
    p = iface.cache_for(0).policy
    assert p.stats.reval_misses >= 1


def test_punch_propagates_eagerly_under_timeout(world):
    """Punches are destructive: even the timeout policy drops the punched
    object's pages everywhere at once (incl. the puncher's own cache)."""
    pool, dfs = world
    iface = make_interface("posix-cached:timeout=5.0", dfs)
    h = iface.create("/d/pn", client_node=0, process=0)
    h.write_at(0, b"doomed!")
    h.fsync()
    h.read_at(0, 7)
    assert iface.cache_for(0).cached_bytes() > 0
    h.obj.punch()
    assert iface.cache_for(0).cached_bytes() == 0


def test_own_writes_do_not_self_invalidate_under_timeout(world):
    pool, dfs = world
    iface = make_interface("posix-cached:timeout=0.25", dfs)
    h = iface.create("/d/own", client_node=0, process=0)
    for i in range(4):
        h.write_at(i * 64, bytes([65 + i]) * 64)
        h.fsync()                # own flush renews the remembered token
        pool.sim.clock.advance(0.5)
        assert bytes(h.read_at(i * 64, 64)) == bytes([65 + i]) * 64
    p = iface.cache_for(0).policy
    assert p.stats.reval_misses == 0         # never dropped our own entry


# ---------------- tx semantics are policy-independent ----------------
@pytest.mark.parametrize("mount", ["posix-cached",
                                   "posix-cached:timeout=1.0"])
def test_commit_barrier_flushes_under_every_policy(world, mount):
    pool, dfs = world
    iface = make_interface(mount, dfs)
    h0 = iface.create(f"/d/tx_{mount.replace(':', '_')}",
                      client_node=0, process=0)
    tx = dfs.cont.tx_begin()
    h = iface.dup(h0, client_node=0, process=0, tx=tx)
    h.write_at(0, b"T" * 128)
    assert iface.cache_for(0).dirty_bytes() > 0
    tx.commit()                              # barrier flushes staged bytes
    assert iface.cache_for(0).dirty_bytes() == 0
    plain = make_interface("posix", dfs)
    got = plain.open(f"/d/tx_{mount.replace(':', '_')}",
                     client_node=1, process=9).read_at(0, 128)
    np.testing.assert_array_equal(got, np.frombuffer(b"T" * 128, np.uint8))


@pytest.mark.parametrize("mount", ["posix-cached",
                                   "posix-cached:timeout=1.0"])
def test_abort_drops_staged_state_under_every_policy(world, mount):
    pool, dfs = world
    iface = make_interface(mount, dfs)
    path = f"/d/ab_{mount.replace(':', '_')}"
    h0 = iface.create(path, client_node=0, process=0)
    tx = dfs.cont.tx_begin()
    h = iface.dup(h0, client_node=0, process=0, tx=tx)
    h.write_at(0, b"garbage")
    tx.abort()
    h2 = iface.open(path, client_node=0, process=1)
    assert bytes(h2.read_at(0, 7)) == b"\0" * 7


# ---------------- engine version tokens ----------------
def test_engine_version_tokens_move_on_mutation(world):
    pool, dfs = world
    obj = dfs.cont.open_array("file:/d/tok")
    t0 = object_token(obj)
    obj.write(0, b"v1" * 100)
    t1 = object_token(obj)
    assert t1 > t0
    obj.write(0, b"v2" * 100)
    t2 = object_token(obj)
    assert t2 > t1
    obj.punch()
    assert object_token(obj) != t2
