from .loss import chunked_softmax_xent, lm_loss
from .optimizer import (OptConfig, adafactor_init, adafactor_update,
                        adamw_init, adamw_update, opt_init, opt_state_shapes,
                        opt_update)
from .train_step import (compress_grads, global_norm, make_eval_step,
                         make_train_step)

__all__ = ["OptConfig", "adafactor_init", "adafactor_update", "adamw_init",
           "adamw_update", "chunked_softmax_xent", "compress_grads",
           "global_norm", "lm_loss", "make_eval_step", "make_train_step",
           "opt_init", "opt_state_shapes", "opt_update"]
