"""Mixture-of-Experts blocks (arctic-480b, qwen3-moe).

Dispatch strategy (TPU/GSPMD-native, see DESIGN.md §4): activations are
sharded batch-over-data and *replicated* over the model axis; experts are
sharded expert-over-model.  Tokens are grouped by data shard (`G` groups,
group dim carries the 'data' sharding), and within each group we do an
index-based (sort-free) dispatch:

  top-k -> per-(group, expert) slot assignment via a one-hot-free cumsum
  rank -> gather rows into an (G, E, C, d) buffer -> expert einsum
  (E sharded) -> scatter-add back -> partial sums psum over 'model'.

Because x is replicated across the model axis, the expert gather is LOCAL;
the only collective is the combine all-reduce — the same volume as a
Megatron TP FFN.  No (T, E, C) one-hot einsum: HLO FLOPs stay honest, which
matters for the MODEL_FLOPS/HLO_FLOPs roofline ratio.

Tokens overflowing an expert's capacity C = ceil(T_g * k / E * cf) are
dropped (standard dropped-token semantics); tests verify equality with the
dense mixture reference when cf is generous.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import _dtype, _init, apply_mlp, init_mlp


def init_moe(key, cfg) -> dict:
    keys = jax.random.split(key, 4)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = _dtype(cfg)
    p = {
        "router": _init(keys[0], (d, E), scale=0.02, dtype=jnp.float32),
        "w_gate": _init(keys[1], (E, d, ff), dtype=dt),
        "w_up": _init(keys[2], (E, d, ff), dtype=dt),
        "w_down": _init(keys[3], (E, ff, d), dtype=dt),
    }
    if cfg.moe_dense_ff:
        p["dense"] = init_mlp(jax.random.fold_in(key, 7), cfg,
                              d_ff=cfg.moe_dense_ff)
    return p


@jax.custom_vjp
def _expert_ffn(ei, wg, wu, wd):
    out, _ = _expert_ffn_fwd(ei, wg, wu, wd)
    return out


def _expert_ffn_fwd(ei, wg, wu, wd):
    from .layers import shard_expert
    a = jnp.einsum("gecd,edf->gecf", ei, wg)
    b = jnp.einsum("gecd,edf->gecf", ei, wu)
    h = jax.nn.silu(a) * b
    out = shard_expert(jnp.einsum("gecf,efd->gecd", h, wd))
    return out, (ei, wg, wu, wd, a, b)


def _expert_ffn_bwd(res, dout):
    """Hand-written backward: every einsum keeps E as a batch dim on both
    operands AND the output, with explicit sharding constraints, so no
    all-gather of (E, C, d)-sized tensors can appear (H9)."""
    from .layers import shard_expert
    ei, wg, wu, wd, a, b = res
    sig = jax.nn.sigmoid(a.astype(jnp.float32)).astype(a.dtype)
    silu_a = a * sig
    h = silu_a * b
    dout = shard_expert(dout)
    dh = shard_expert(jnp.einsum("gecd,efd->gecf", dout, wd))
    dwd = jnp.einsum("gecf,gecd->efd", h, dout)
    db = dh * silu_a
    da = dh * b * (sig + a * sig * (1 - sig))
    da = shard_expert(da)
    db = shard_expert(db)
    dei = shard_expert(jnp.einsum("gecf,edf->gecd", da, wg)
                       + jnp.einsum("gecf,edf->gecd", db, wu))
    dwg = jnp.einsum("gecd,gecf->edf", ei, da)
    dwu = jnp.einsum("gecd,gecf->edf", ei, db)
    return dei, dwg.astype(wg.dtype), dwu.astype(wu.dtype), \
        dwd.astype(wd.dtype)


_expert_ffn.defvjp(_expert_ffn_fwd, _expert_ffn_bwd)


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(np.ceil(tokens_per_group * cfg.experts_per_token
                    / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)


def moe_ffn(params: dict, x: jnp.ndarray, cfg, n_groups: int = 1):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar).

    n_groups should equal the data-axis size so the group dim can carry the
    'data' sharding (launch/mesh.py sets it; smoke tests use 1).
    """
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.experts_per_token
    if T % n_groups:
        n_groups = 1
    G = n_groups
    Tg = T // G
    C = _capacity(Tg, cfg)

    from .layers import shard_batch, shard_expert

    xf = shard_batch(x.reshape(G, Tg, d))
    logits = xf.astype(jnp.float32) @ params["router"]          # (G, Tg, E)
    probs = shard_batch(jax.nn.softmax(logits, axis=-1))
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalise

    # ---- slot assignment: rank of each (token, choice) within its expert ---
    # Routing tensors are (G: data)-sharded and replicated over 'model' —
    # every model shard computes identical cheap int math, no collectives
    # (H8, EXPERIMENTS.md §Perf).  One-hot flattened choices-first so lower
    # k wins under capacity pressure.
    oh = shard_batch(jax.nn.one_hot(expert_idx, E, dtype=jnp.int32))
    oh_flat = shard_batch(oh.transpose(0, 2, 1, 3).reshape(G, k * Tg, E))
    ranks = shard_batch(jnp.cumsum(oh_flat, axis=1) - oh_flat)   # (G,kTg,E)
    slot_flat = jnp.sum(ranks * oh_flat, axis=-1)                # (G, kTg)
    slot = slot_flat.reshape(G, k, Tg).transpose(0, 2, 1)        # (G, Tg, k)
    keep = slot < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # ---- dispatch into (G, E, C, d) expert buffers (local gather: xf is
    # replicated across 'model', indices too) ----
    flat_pos = shard_batch(jnp.where(keep, expert_idx * C + slot, E * C))
    src_row = jnp.repeat(jnp.arange(Tg), k)                      # (Tg*k,)
    buf = jnp.zeros((G, E * C + 1, d), x.dtype)
    buf = jax.vmap(
        lambda b, fp, xg: b.at[fp.reshape(-1)].set(xg[src_row])
    )(buf, flat_pos, xf)
    expert_in = shard_expert(buf[:, : E * C].reshape(G, E, C, d))

    # ---- expert FFN (E sharded over 'model') ----
    if getattr(cfg, "moe_expert_cvjp", False):
        # H9 (refuted on qwen3 — kept for study, see EXPERIMENTS.md §Perf):
        # hand-written backward with explicit constraints.
        expert_out = _expert_ffn(expert_in, params["w_gate"],
                                 params["w_up"], params["w_down"])
    else:
        a = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])
        b = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
        expert_out = shard_expert(
            jnp.einsum("gecf,efd->gecd", jax.nn.silu(a) * b,
                       params["w_down"]))

    # ---- combine: scatter-add slots back to tokens.  Each model shard
    # scatters its local experts' slots into a (Tg+1, d) buffer; the
    # partial results meet in ONE bf16 psum per layer — the same volume as
    # a Megatron TP FFN, with no cross-shard gathers (H8). ----
    tok_for_slot = jnp.full((G, E * C + 1), Tg, jnp.int32)
    tok_for_slot = jax.vmap(
        lambda t, fp: t.at[fp.reshape(-1)].set(src_row)
    )(tok_for_slot, flat_pos)
    gate_for_slot = jnp.zeros((G, E * C + 1), x.dtype)
    gate_for_slot = jax.vmap(
        lambda gg, fp, gv: gg.at[fp.reshape(-1)].set(
            gv.reshape(-1).astype(x.dtype))
    )(gate_for_slot, flat_pos, gate_vals)
    out_flat = jnp.concatenate(
        [expert_out.reshape(G, E * C, d),
         jnp.zeros((G, 1, d), expert_out.dtype)], axis=1)
    y = jax.vmap(
        lambda of, tf, gf: jnp.zeros((Tg + 1, d), x.dtype)
        .at[tf].add(of * gf[:, None])
    )(out_flat, tok_for_slot, gate_for_slot)[:, :Tg]
    y = shard_batch(y)

    # ---- aux load-balance loss (Switch-style) ----
    density = jnp.mean(oh.astype(jnp.float32).sum(2), axis=1)     # (G, E)
    router_prob = jnp.mean(probs, axis=1)                         # (G, E)
    aux = jnp.mean(jnp.sum(density * router_prob, axis=-1)) * E

    y = y.reshape(B, S, d)
    if "dense" in params:  # arctic: parallel dense residual branch
        y = y + apply_mlp(params["dense"], x, cfg)
    return y, aux
