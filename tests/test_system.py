"""End-to-end behaviour tests: the full train -> fail -> recover -> resume
story, IOR claim validation, and serving consistency through the store."""
import argparse

import numpy as np
import pytest


def _train_args(**over):
    base = dict(arch="deepseek-7b", smoke=True, steps=18, batch=4, seq=48,
                vocab=128, interface="dfs", oclass="S2",
                ckpt_oclass="RP_2GX", ckpt_layout="sharded", ckpt_every=5,
                kill_at_step=0, grad_compression=False, servers=4, workers=4,
                corpus_tokens=60_000, shard_tokens=8192, seed=0)
    base.update(over)
    return argparse.Namespace(**base)


def test_train_end_to_end_loss_decreases():
    from repro.launch.train import run
    out = run(_train_args())
    assert out["steps"] == 18 and out["restarts"] == 0
    assert out["final_loss"] < out["first_loss"]


def test_train_survives_injected_failure():
    from repro.launch.train import run
    out = run(_train_args(kill_at_step=9, steps=16))
    assert out["restarts"] == 1
    assert out["steps"] == 16
    assert out["final_loss"] < out["first_loss"]


def test_train_with_grad_compression():
    from repro.launch.train import run
    out = run(_train_args(steps=10, grad_compression=True))
    assert out["final_loss"] < out["first_loss"]


def test_train_shared_file_checkpoint_layout():
    from repro.launch.train import run
    out = run(_train_args(steps=8, ckpt_layout="shared"))
    assert out["final_loss"] < out["first_loss"]


def test_ior_claims_hold():
    """The paper's §IV findings (C1..C5) hold in the reproduction."""
    from benchmarks import ior
    rows = ior.main(["--clients", "1", "4", "16", "--out",
                     "/tmp/ior_test.json"])
    checks = ior.check_claims(rows)
    assert len(checks) == 5
    failed = [(n, d) for n, ok, d in checks if not ok]
    assert not failed, failed


def test_serving_consistency_after_ckpt_roundtrip():
    """Restored params must produce identical decode outputs."""
    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, smoke_variant
    from repro.configs.base import ShapeConfig
    from repro.core import Pool, Topology
    from repro.core.interfaces import DFS
    from repro.ckpt import Checkpointer
    from repro.models import init_model, make_inputs
    from repro.serve import make_decode_step, make_prefill_step

    cfg = smoke_variant(ARCHS["chatglm3-6b"])
    key = jax.random.PRNGKey(1)
    params = init_model(key, cfg)

    pool = Pool(Topology(n_server_nodes=2, engines_per_node=2))
    dfs = DFS(pool.create_container("m", oclass="RP_2GX"))
    ck = Checkpointer(dfs, layout="sharded", n_writers=2)
    ck.save(0, params)
    restored = jax.tree.map(jnp.asarray, ck.restore(0, params))

    shape = ShapeConfig("s", 16, 2, "prefill")
    batch = make_inputs(key, cfg, shape)
    lg1, cache1 = make_prefill_step(cfg)(params, batch)
    lg2, cache2 = make_prefill_step(cfg)(restored, batch)
    np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))
    dec = make_decode_step(cfg)
    t1, d1, _ = dec(params, cache1, jnp.zeros((2, 1), jnp.int32),
                    jnp.asarray(15, jnp.int32))
    t2, d2, _ = dec(restored, cache2, jnp.zeros((2, 1), jnp.int32),
                    jnp.asarray(15, jnp.int32))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
