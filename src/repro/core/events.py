"""Event queues — DAOS-style non-blocking I/O.

Every DAOS API call can run asynchronously against an event queue
(daos_eq_create / daos_event_test / daos_eq_poll).  The checkpointer uses this
to overlap checkpoint serialisation + store writes with the next training
steps.  Implementation: a thread pool per queue; an Event is a future with
DAOS test/poll semantics.

``SubmissionQueue`` is the *data-path* sibling: the per-handle queue behind
``FileHandle.write_at_async``/``read_at_async``.  Where ``EventQueue`` runs
arbitrary callables on real threads, the submission queue is deterministic
and threadless — queued IODs execute lazily, in submission order, bounded by
a per-engine in-flight window of ``qd`` — because the cost of concurrency is
charged by the simulation's solver, not by host parallelism.
"""
from __future__ import annotations

import concurrent.futures as _fut
import threading as _threading
import time as _time
from collections import Counter
from typing import Any, Callable, Iterable


class Event:
    def __init__(self, future: _fut.Future) -> None:
        self._future = future

    def test(self) -> bool:
        """Non-blocking completion probe (daos_event_test)."""
        return self._future.done()

    def wait(self, timeout: float | None = None) -> Any:
        return self._future.result(timeout)

    def on_complete(self, fn: Callable[["Event"], Any]) -> "Event":
        """Completion-callback chaining (the daos_event callback slot):
        ``fn(self)`` runs exactly once when this event completes — on the
        worker thread that completed it, or immediately on the caller if
        it already has.  Callbacks are allowed to submit follow-on work to
        the queue; that is the chaining.  The event completes (and
        ``wait`` returns) regardless of what the callback does — an
        exception inside ``fn`` is swallowed by the future machinery, so
        callbacks that can fail must capture their own errors."""
        self._future.add_done_callback(lambda _f: fn(self))
        return self

    @property
    def error(self) -> BaseException | None:
        return self._future.exception() if self._future.done() else None


class EventQueue:
    """daos_eq_*: submit async ops, poll for completions.

    ``depth`` is a real bound: once that many events are in flight,
    ``submit`` first poll-retires completions and, if the queue is still
    full, blocks on the oldest in-flight event before admitting the new one
    (daos_eq semantics — the queue is the backpressure).  Errors of events
    retired that way are not lost: they re-raise at the next ``drain``.

    The queue is thread-safe: completion callbacks (``on_complete``) run
    on worker threads and may submit follow-on events, so the in-flight
    list is guarded by a lock (waits happen outside it).
    """

    def __init__(self, depth: int = 8) -> None:
        self.depth = max(1, int(depth))
        self._pool = _fut.ThreadPoolExecutor(max_workers=self.depth,
                                             thread_name_prefix="repro-eq")
        self._inflight: list[Event] = []
        self._errors: list[BaseException] = []
        self._lock = _threading.Lock()

    def submit(self, fn: Callable, /, *args,
               on_complete: Callable[[Event], Any] | None = None,
               **kwargs) -> Event:
        while True:
            with self._lock:
                if len(self._inflight) < self.depth:
                    ev = Event(self._pool.submit(fn, *args, **kwargs))
                    self._inflight.append(ev)
                    break
            # full: poll-retire completions first, then block on the oldest
            for done in self.poll():
                if done.error is not None:
                    self._errors.append(done.error)
            with self._lock:
                oldest = (self._inflight[0]
                          if len(self._inflight) >= self.depth else None)
            if oldest is None:
                continue
            try:
                oldest.wait()
            except BaseException as exc:  # noqa: BLE001 — re-raised at drain
                self._errors.append(exc)
            with self._lock:
                if self._inflight and self._inflight[0] is oldest:
                    self._inflight.pop(0)
        if on_complete is not None:
            # registered after admission: if the event already completed,
            # the callback fires right here on the submitting thread
            ev.on_complete(on_complete)
        return ev

    def poll(self) -> list[Event]:
        """Return (and retire) completed events.  ``test()`` is snapshotted
        exactly once per event: probing twice would let an event complete
        between the probes and vanish from both the returned and retained
        lists."""
        done: list[Event] = []
        pending: list[Event] = []
        with self._lock:
            for e in self._inflight:
                (done if e.test() else pending).append(e)
            self._inflight[:] = pending
        return done

    def drain(self, timeout: float | None = None) -> None:
        """Wait for everything in flight; re-raise the first error.

        ``timeout`` is a deadline over the whole drain, not a per-event
        allowance — draining N slow events takes at most ``timeout``
        seconds before TimeoutError, not N * timeout."""
        deadline = None if timeout is None else _time.monotonic() + timeout
        errs = self._errors
        self._errors = []
        with self._lock:
            inflight = list(self._inflight)
        for e in inflight:
            try:
                left = (None if deadline is None
                        else max(0.0, deadline - _time.monotonic()))
                e.wait(left)
            except BaseException as exc:  # noqa: BLE001 — surfaced below
                errs.append(exc)
        # retire only the snapshot: events chained in by completion
        # callbacks DURING the drain stay in flight for the next one
        with self._lock:
            self._inflight[:] = [e for e in self._inflight
                                 if e not in inflight]
        if errs:
            raise errs[0]

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    def close(self) -> None:
        try:
            self.drain()
        finally:
            self._pool.shutdown(wait=True)

    def __enter__(self) -> "EventQueue":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class QueuedOp:
    """One queued IOD: an event with DAOS test/wait semantics, completed by
    its queue's deterministic in-order execution."""

    __slots__ = ("_sq", "_fn", "engines", "_done", "_result", "_error")

    def __init__(self, sq: "SubmissionQueue", fn: Callable[[], Any],
                 engines: Iterable[int] = ()) -> None:
        self._sq = sq
        self._fn = fn
        self.engines = frozenset(engines)
        self._done = False
        self._result: Any = None
        self._error: BaseException | None = None

    def _run(self) -> None:
        if self._done:
            return
        try:
            self._result = self._fn()
        except BaseException as exc:  # noqa: BLE001 — surfaced at wait/flush
            self._error = exc
        self._done = True

    def test(self) -> bool:
        return self._done

    def wait(self, timeout: float | None = None) -> Any:
        """Force completion.  Ops ahead of this one in the queue execute
        first (submission order is completion order — ordered commit)."""
        self._sq.flush_until(self)
        if self._error is not None:
            raise self._error
        return self._result

    @property
    def error(self) -> BaseException | None:
        return self._error if self._done else None


class SubmissionQueue:
    """Per-handle async submission: at most ``qd`` IODs in flight per engine.

    Submission beyond the window retires the oldest queued ops first (the
    submitting process blocks on a completion slot — exactly the
    backpressure the solver's in-flight window models).  ``qd <= 1``
    degenerates to immediate execution: the async API then produces
    byte- and flow-identical accounting to the sync path.
    """

    def __init__(self, qd: int = 1) -> None:
        self.qd = max(1, int(qd))
        self._pending: list[QueuedOp] = []
        self._first_error: BaseException | None = None
        self._executing = False

    # -- internals -----------------------------------------------------------
    def _run_op(self, op: QueuedOp) -> None:
        # ops may re-enter the handle's sync paths (cache fills, RMW reads);
        # the guard stops such nested calls from being queued behind the op
        # that issued them, which would deadlock the in-order contract
        self._executing = True
        try:
            op._run()
        finally:
            self._executing = False
        if op._error is not None and self._first_error is None:
            self._first_error = op._error

    def _over_window(self) -> bool:
        seen: Counter = Counter()
        for op in self._pending:
            for key in (op.engines or (None,)):
                seen[key] += 1
                if seen[key] > self.qd:
                    return True
        return False

    # -- submission ----------------------------------------------------------
    def submit(self, fn: Callable[[], Any],
               engines: Iterable[int] = ()) -> QueuedOp:
        op = QueuedOp(self, fn, engines)
        if self.qd <= 1 or self._executing:
            self._run_op(op)
            return op
        self._pending.append(op)
        while self._pending and self._over_window():
            self._run_op(self._pending.pop(0))
        return op

    # -- completion ----------------------------------------------------------
    def flush_until(self, op: QueuedOp) -> None:
        if op._done:
            return
        while self._pending:
            nxt = self._pending.pop(0)
            self._run_op(nxt)
            if nxt is op:
                return

    def flush(self) -> None:
        """Retire every queued op in submission order; re-raise the first
        error any op in this queue ever hit (including ones force-retired
        by window backpressure)."""
        while self._pending:
            self._run_op(self._pending.pop(0))
        err, self._first_error = self._first_error, None
        if err is not None:
            raise err

    def discard(self) -> None:
        """Abort path: queued-but-unexecuted ops never reach the engines.
        Each is completed with a TxStateError so a caller holding its event
        learns the write was torn away rather than silently dropped."""
        from .transactions import TxStateError
        for op in self._pending:
            op._done = True
            op._error = TxStateError(
                "queued submission discarded (transaction aborted)")
        self._pending.clear()
        self._first_error = None

    @property
    def inflight(self) -> int:
        return len(self._pending)
