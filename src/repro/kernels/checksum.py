"""Pallas TPU kernel: weighted uint32 checksum (end-to-end integrity).

DAOS checksums every extent client-side; at TPU speeds a multi-GiB
checkpoint shard would otherwise serialise on the host CPU.  The weighted
checksum (see ``repro.core.integrity``) is tile-decomposable:

    csum = sum_t  W^(t*T) * ( sum_j W^(j+1) * x[t*T + j] )

so each grid step reduces one (8, 128) VMEM tile of uint32 words (T = 1024)
against a resident weight tile, scales by the per-tile factor W^(t*T), and
accumulates into a (1, 1) output that stays pinned across the grid.

TPU notes: (8, 128) is the float32/int32 native VREG tile; the multiply-add
runs on the VPU (integer path), no MXU involvement; the weight tile and the
accumulator live in VMEM for the whole sweep, so HBM traffic is exactly one
read of the data — the kernel is memory-bound by construction, which is the
roofline-optimal shape for a reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 8
TILE_COLS = 128
TILE = TILE_ROWS * TILE_COLS  # 1024 words per grid step


def _checksum_kernel(scale_ref, words_ref, weights_ref, out_ref):
    """One grid step: out += scale[t] * sum(weights * words_tile)."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        out_ref[0, 0] = jnp.uint32(0)

    tile = words_ref[...]                       # (8, 128) uint32
    weights = weights_ref[...]                  # (8, 128) uint32
    partial = jnp.sum(weights * tile, dtype=jnp.uint32)
    out_ref[0, 0] = out_ref[0, 0] + scale_ref[0] * partial


def checksum_words_pallas(words: jnp.ndarray, scales: jnp.ndarray,
                          weights: jnp.ndarray,
                          interpret: bool = True) -> jnp.ndarray:
    """words: (n_tiles*8, 128) uint32; scales: (n_tiles,) uint32 = W^(t*1024);
    weights: (8, 128) uint32 = W^1..W^1024 row-major. Returns (1,1) uint32."""
    n_tiles = words.shape[0] // TILE_ROWS
    return pl.pallas_call(
        _checksum_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1,), lambda t: (t,)),                 # scale
            pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda t: (t, 0)),  # words
            pl.BlockSpec((TILE_ROWS, TILE_COLS), lambda t: (0, 0)),  # weights
        ],
        out_specs=pl.BlockSpec((1, 1), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.uint32),
        interpret=interpret,
    )(scales, words, weights)
