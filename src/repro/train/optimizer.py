"""Optimizers as pure pytree transforms: AdamW and Adafactor.

Adafactor (factored second moment + bf16 first moment) is what lets the
480 B-param MoE fit 16 GB/chip at 256-way sharding — Adam's 8 B/param fp32
state cannot (DESIGN.md §5).  Optimizer state inherits the parameter
PartitionSpecs leaf-for-leaf (factored leaves drop the corresponding axis),
so ZeRO-style sharding falls out of the param specs for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    # adafactor
    decay_offset: float = 1e-3
    clip_rms: float = 1.0


# --------------------------- AdamW ---------------------------

def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, oc: OptConfig):
    c = state["count"] + 1
    b1, b2 = oc.b1, oc.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** c.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** c.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - oc.lr * step).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": c}


# --------------------------- Adafactor ---------------------------

def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def adafactor_init(params) -> dict:
    def vr(p):  # row stats (reduce last dim)
        return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                else jnp.zeros(p.shape, jnp.float32))

    def vc(p):  # col stats (reduce 2nd-to-last dim)
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p) else jnp.zeros((1,), jnp.float32))

    return {"vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16),
                              params),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, oc: OptConfig):
    c = state["count"] + 1
    beta2 = 1.0 - (c.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, vr, vc, m, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if _factored(p):
            vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr2 / jnp.mean(vr2, axis=-1, keepdims=True) + 1e-30)
            cfac = jax.lax.rsqrt(vc2 + 1e-30)
            update = g * rfac[..., None] * cfac[..., None, :]
        else:
            vr2 = beta2 * vr + (1 - beta2) * g2
            vc2 = vc
            update = g * jax.lax.rsqrt(vr2 + 1e-30)
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms / oc.clip_rms)
        m2 = (oc.b1 * m.astype(jnp.float32) + (1 - oc.b1) * update) \
            .astype(jnp.bfloat16)
        step = m2.astype(jnp.float32) + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - oc.lr * step).astype(p.dtype), \
            vr2, vc2, m2

    out = jax.tree.map(upd, grads, state["vr"], state["vc"], state["m"],
                       params)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), {"vr": pick(1), "vc": pick(2), "m": pick(3), "count": c}


# --------------------------- facade ---------------------------

def opt_init(name: str, params):
    return adamw_init(params) if name == "adamw" else adafactor_init(params)


def opt_update(name: str, grads, state, params, oc: OptConfig | None = None):
    oc = oc or OptConfig(name=name)
    if name == "adamw":
        return adamw_update(grads, state, params, oc)
    return adafactor_update(grads, state, params, oc)


def opt_state_shapes(name: str, param_shapes_tree):
    """eval_shape of the optimizer state (dry-run path)."""
    def fake(s):
        return jnp.zeros(s.shape, s.dtype)
    return jax.eval_shape(
        lambda: opt_init(name, jax.tree.map(fake, param_shapes_tree)))
