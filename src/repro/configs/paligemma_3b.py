"""paligemma-3b [vlm] — 18L d2048 8H MQA(kv=1) ff16384 V257216.

Gemma-2B text backbone behind a SigLIP vision stub: ``input_specs``
supplies 256 precomputed patch embeddings as a bidirectional prefix, text
is causal (prefix-LM masking).  [arXiv:2407.07726]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216,
    n_prefix_tokens=256, mlp="geglu", rope_theta=10000.0,
)
