"""Failure & rebuild tier benchmark: degraded reads, rebuild-vs-foreground
contention, serving SLO across a failover, and the failure-schedule
conformance sweep.

The failure domain runs through the same costed pipeline as the healthy
path — degraded reads charge the survivors they actually touch, rebuild
moves its bytes as simulator flows (standalone phases or background debt
inside foreground phases), and recovery fences client caches through the
real coherence plane.  This driver measures what that costs:

* ``--mode degraded`` — per-oclass read bandwidth with one engine down
                        vs healthy: RP_2G1 reads fail over to the
                        surviving replica, EC_4P1 reads XOR-reconstruct
                        from the surviving lanes + parity, and an
                        unprotected SX read raises ``DataLossError``
                        instead of fabricating bytes (claim F1).
* ``--mode rebuild``  — rebuild-vs-foreground contention: an unthrottled
                        standalone rebuild sets the floor, then a
                        throttled rebuild streams its bytes as
                        background debt inside foreground read phases
                        and both sides are measured (claim F2).
* ``--mode slo``      — a serving fleet mid-sweep failover: decode node
                        (and its co-resident server engines) dies
                        between waves, the ``FailureDetector`` feeds
                        ``mark_down``, sessions fail over and restore
                        degraded — p95 stays inside the SLO and zero
                        routes land on the dead node (claim F3).
* ``--mode conform``  — the failure-schedule conformance sweep: the
                        coherence oracle with engine kill / costed
                        rebuild / fenced restore injected mid-
                        interleaving; every read byte-exact across
                        >= 50 seeds (claim F4).
* ``--mode all``      — everything.

Claims validated:

* **F1** — RP_2G1 degraded-read bandwidth >= 70% of the healthy read
  (one replica lost, the other serves at full stripe width minus the
  dead lanes), and SX loss is loud: ``DataLossError``, not silence.
* **F2** — a throttled rebuild preserves >= 80% of foreground read
  bandwidth while finishing within 3x the unthrottled rebuild time:
  contention is real but bounded, in both directions.
* **F3** — after a mid-sweep node failure the serving p95 stays inside
  the SLO, at least one failover is observed, and no post-failure route
  or speculation targets the dead node.
* **F4** — torn-offload and staleness guarantees survive an injected
  failure schedule: every checked read of the conformance oracle is
  byte-exact across the full seed matrix, and the schedule really
  kills engines (no vacuous pass).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.core import Pool, Topology, bandwidth            # noqa: E402
from repro.core.interfaces import DFS                       # noqa: E402
from repro.core.redundancy import DataLossError             # noqa: E402
from repro.ft import FailureDetector                        # noqa: E402
from repro.serve import KVCacheStore, ServeScheduler        # noqa: E402

ARTIFACTS = ROOT / "artifacts"
MIB = 1 << 20


def make_pool(clients: int = 8) -> Pool:
    topo = Topology(n_server_nodes=8, engines_per_node=2,
                    n_client_nodes=clients, procs_per_client_node=1)
    # materialized engines: degraded reads and rebuild really move the
    # bytes, so byte-identity checks below are meaningful
    return Pool(topo, materialize=True)


def synth(nbytes: int, seed: int = 0) -> bytes:
    rng = np.random.default_rng(seed)
    return rng.integers(0, 255, nbytes, np.uint8).tobytes()


# --------------------------------------------------------------- degraded --
def degraded(oclass: str, mib: int) -> dict:
    """Healthy vs one-engine-down read bandwidth for one object class."""
    pool = make_pool()
    cont = pool.create_container("ft", oclass=oclass, stripe_cell=MIB)
    obj = cont.open_array("a", oclass=oclass)
    data = synth(mib * MIB)
    obj.write(0, data)
    with pool.sim.phase() as hp:
        got = obj.read(0, len(data))
    np.testing.assert_array_equal(got, np.frombuffer(data, np.uint8))
    lay = obj._layout()
    oc = obj.oclass
    if oc.ec_data:          # kill a DATA lane: forces XOR reconstruction
        dead = obj._cell_engines(lay, 0)[0]
    else:
        dead = lay.replicas_for_chunk(0)[0]
    pool.fail_engine(dead)
    row = {"mode": "degraded", "oclass": oclass,
           "mib": mib, "dead_engine": dead,
           "healthy_gib_s": round(bandwidth(len(data), hp.elapsed), 3)}
    try:
        with pool.sim.phase() as dp:
            got = obj.read(0, len(data))
    except DataLossError as e:
        row.update(degraded_gib_s=0.0, ratio=0.0,
                   data_loss_raised=True, error=str(e)[:80])
        return row
    np.testing.assert_array_equal(got, np.frombuffer(data, np.uint8))
    dbw = bandwidth(len(data), dp.elapsed)
    row.update(degraded_gib_s=round(dbw, 3),
               ratio=round(dbw / max(1e-9, row["healthy_gib_s"]), 3),
               data_loss_raised=False)
    return row


# ---------------------------------------------------------------- rebuild --
def _rebuild_world(mib: int):
    pool = make_pool()
    cont = pool.create_container("ft", oclass="RP_2G1", stripe_cell=MIB)
    vic = cont.open_array("victim")          # what rebuild re-replicates
    fg = cont.open_array("fg")               # what the foreground reads
    vic.write(0, synth(mib * MIB, seed=1))
    fg.write(0, synth(mib * MIB, seed=2))
    return pool, cont, vic, fg


def rebuild_contention(mib: int, rounds: int, fg_factor: int) -> dict:
    """Unthrottled-vs-throttled rebuild against a live foreground.

    The unthrottled run measures the rebuild floor (all bytes in one
    standalone phase).  The throttled run splits the same bytes into
    ``rounds`` budget slices, each issued as background debt inside a
    foreground phase reading ``fg_factor`` budgets' worth of data — the
    contention frontier claim F2 bounds from both sides."""
    # -- foreground baseline: no failure, no rebuild
    pool, cont, vic, fg = _rebuild_world(mib)
    fg_bytes = mib * MIB
    with pool.sim.phase() as bp:
        fg.read(0, fg_bytes)
    bw_base = bandwidth(fg_bytes, bp.elapsed)

    # -- unthrottled rebuild floor (standalone phase, nothing else runs)
    dead = vic._layout().replicas_for_chunk(0)[0]
    pool2, *_ = _rebuild_world(mib)
    pool2.fail_engine(dead)
    t0 = pool2.sim.clock.now
    stats = pool2.rebuild()
    t_fast = pool2.sim.clock.now - t0
    total = stats["moved_bytes"]

    # -- throttled rebuild inside foreground phases
    pool3, cont3, vic3, fg3 = _rebuild_world(mib)
    pool3.fail_engine(dead)
    rb = pool3.rebuilder()
    budget = max(1, total // rounds)
    read_per_round = min(fg_bytes, fg_factor * budget)
    t0 = pool3.sim.clock.now
    fg_read = fg_time = 0.0
    waves = 0
    while not rb.done:
        with pool3.sim.phase() as ph:
            fg3.read(0, read_per_round)
            rb.step(budget)
        fg_read += read_per_round
        fg_time += ph.elapsed
        waves += 1
    t_throttled = pool3.sim.clock.now - t0
    bw_contended = bandwidth(fg_read, fg_time)
    # the rebuilt copy is byte-exact through the replacement
    pool3.restore_engine(dead)
    got = vic3.read(0, mib * MIB)
    np.testing.assert_array_equal(got,
                                  np.frombuffer(synth(mib * MIB, seed=1),
                                                np.uint8))
    return {"mode": "rebuild", "mib": mib, "rounds": waves,
            "moved_mib": round(total / MIB, 1),
            "rebuild_floor_s": round(t_fast, 4),
            "rebuild_throttled_s": round(t_throttled, 4),
            "slowdown": round(t_throttled / max(1e-9, t_fast), 2),
            "fg_base_gib_s": round(bw_base, 3),
            "fg_contended_gib_s": round(bw_contended, 3),
            "fg_retention": round(bw_contended / max(1e-9, bw_base), 3),
            "bg_hidden_fraction": round(pool3.sim.bg_hidden_fraction(), 3)}


# -------------------------------------------------------------------- slo --
def slo_sweep(sessions: int, nodes: int, rounds: int, n_leaves: int,
              leaf_kib: int, slo_ms: float) -> dict:
    """Serving waves with a mid-sweep node failure: decode node
    ``nodes - 1`` (and the server engines co-resident on that physical
    node) dies between waves; the detector marks it down and the fleet
    fails over onto the survivors, restoring degraded."""
    pool = make_pool(clients=max(8, nodes))
    cont = pool.create_container("serve", oclass="RP_2G1")
    dfs = DFS(cont)
    store = KVCacheStore(dfs, interface="posix-cached",
                         verify_on_restore=False)
    sched = ServeScheduler(store, nodes=range(nodes),
                           speculate_window=leaf_kib << 9)
    rng = np.random.default_rng(0)
    names = [f"s{i:03d}" for i in range(sessions)]
    for i, s in enumerate(names):
        cache = {f"l{j:02d}": rng.integers(0, 255, (leaf_kib << 10,),
                                           np.uint8)
                 for j in range(n_leaves)}
        sched.offload(s, cache)
        n = sched.begin(s, node=i % nodes)   # seed affinity across fleet
        sched.end(s, n)

    det = FailureDetector(pool)
    dead_node = nodes - 1
    lat_pre, lat_post = [], []
    routed_post: set[int] = set()
    last_node: dict[str, int] = {}
    failovers = 0
    for rnd in range(rounds):
        if rnd == rounds // 2:
            # the physical node dies: its server engines AND the decode
            # client on it — data survives via RP_2G1, routing via the
            # detector-driven mark_down
            pool.fail_node(dead_node)
            for ev in det.poll(rnd):
                if ev.kind == "node" and ev.ident < nodes:
                    sched.mark_down(ev.ident)
        for s in names:
            n = sched.begin(s)
            with pool.sim.phase() as ph:
                sched.speculated_manifest(s, n)
                store.restore(s, client_node=n)
            sched.end(s, n)
            (lat_post if rnd >= rounds // 2 else lat_pre).append(ph.elapsed)
            if rnd >= rounds // 2:
                routed_post.add(n)
                # a session whose warm node died landing elsewhere is
                # the failover the claim counts
                if last_node.get(s) == dead_node and n != dead_node:
                    failovers += 1
            last_node[s] = n
        pool.sim.clock.advance(0.05)         # think time between waves
    p95_pre, p95_post = (float(np.percentile(ls, 95)) * 1e3
                         for ls in (lat_pre, lat_post))
    st = sched.stats()
    return {"mode": "slo", "sessions": sessions, "nodes": nodes,
            "rounds": rounds, "dead_node": dead_node,
            "p95_pre_ms": round(p95_pre, 3),
            "p95_post_ms": round(p95_post, 3), "slo_ms": slo_ms,
            "slo_ok": bool(p95_post <= slo_ms),
            "dead_routed": bool(dead_node in routed_post),
            "failovers": failovers,
            "sched_failovers": st["failovers"],
            "speculations": st["speculations"]}


# ---------------------------------------------------------------- conform --
def conformance(seeds: int, fleet: str) -> dict:
    """Drive the failure-schedule conformance harness (the same oracle
    tier-1 runs) across the seed matrix and report coverage."""
    sys.path.insert(0, str(ROOT / "tests"))
    from test_coherence_conformance import _FTWorld, FLEETS  # noqa: E402
    cycles = checked = 0
    failures: list[str] = []
    for seed in range(seeds):
        w = _FTWorld(FLEETS[fleet], seed)
        try:
            w.run()
        except AssertionError as e:
            failures.append(f"seed {seed}: {e}")
        cycles += w.fail_cycles
        checked += w.checked_reads
    return {"mode": "conform", "fleet": fleet, "seeds": seeds,
            "fail_cycles": cycles, "checked_reads": checked,
            "byte_exact": not failures, "failures": failures[:5]}


# ----------------------------------------------------------------- claims --
def check_claims(rows: list[dict]) -> list[dict]:
    out = []
    drows = {r["oclass"]: r for r in rows if r["mode"] == "degraded"}
    if drows:
        rp = drows.get("RP_2G1")
        sx = drows.get("SX")
        ok = (rp is not None and rp["ratio"] >= 0.7
              and (sx is None or sx["data_loss_raised"]))
        ec = drows.get("EC_4P1")
        detail = (f"RP_2G1 {rp['healthy_gib_s']:.2f} -> "
                  f"{rp['degraded_gib_s']:.2f} GiB/s "
                  f"({rp['ratio']:.0%})" if rp else "RP_2G1 row missing")
        if ec:
            detail += (f"; EC_4P1 reconstructs at {ec['ratio']:.0%}")
        if sx:
            detail += (f"; SX raises DataLossError: "
                       f"{sx['data_loss_raised']}")
        out.append({"claim": "F1 degraded RP read >= 70% of healthy; "
                             "unprotected loss is loud",
                    "ok": bool(ok), "detail": detail})
    rrows = [r for r in rows if r["mode"] == "rebuild"]
    if rrows:
        r = rrows[0]
        ok = r["fg_retention"] >= 0.8 and r["slowdown"] <= 3.0
        out.append({"claim": "F2 throttled rebuild keeps >= 80% "
                             "foreground bw within 3x rebuild time",
                    "ok": bool(ok),
                    "detail": f"fg {r['fg_base_gib_s']:.2f} -> "
                              f"{r['fg_contended_gib_s']:.2f} GiB/s "
                              f"({r['fg_retention']:.0%}), rebuild "
                              f"{r['rebuild_floor_s'] * 1e3:.1f} -> "
                              f"{r['rebuild_throttled_s'] * 1e3:.1f} ms "
                              f"({r['slowdown']:.1f}x)"})
    srows = [r for r in rows if r["mode"] == "slo"]
    if srows:
        r = srows[0]
        ok = (r["slo_ok"] and not r["dead_routed"] and r["failovers"] > 0)
        out.append({"claim": "F3 serving p95 in SLO across mid-sweep "
                             "failover; zero routes to the dead node",
                    "ok": bool(ok),
                    "detail": f"p95 {r['p95_pre_ms']:.2f} -> "
                              f"{r['p95_post_ms']:.2f} ms (SLO "
                              f"{r['slo_ms']:.0f} ms), failovers "
                              f"{r['failovers']}, dead routed: "
                              f"{r['dead_routed']}"})
    crows = [r for r in rows if r["mode"] == "conform"]
    if crows:
        ok = all(r["byte_exact"] and r["fail_cycles"] > 0 for r in crows)
        seeds = sum(r["seeds"] for r in crows)
        cyc = sum(r["fail_cycles"] for r in crows)
        reads = sum(r["checked_reads"] for r in crows)
        out.append({"claim": "F4 torn-offload guarantees survive the "
                             "injected failure schedule, byte-exact",
                    "ok": bool(ok),
                    "detail": f"{seeds} seeds, {cyc} failure cycles, "
                              f"{reads} checked reads, all byte-exact: "
                              f"{all(r['byte_exact'] for r in crows)}"})
    return out


def main(argv=None) -> list[dict]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="all",
                    choices=["degraded", "rebuild", "slo", "conform",
                             "all"])
    ap.add_argument("--oclasses", nargs="+",
                    default=["RP_2G1", "RP_3GX", "EC_4P1", "SX"])
    ap.add_argument("--degraded-mib", type=int, default=64)
    ap.add_argument("--rebuild-mib", type=int, default=64)
    ap.add_argument("--rebuild-rounds", type=int, default=8,
                    help="budget slices the throttled rebuild is split "
                         "into (one foreground phase each)")
    ap.add_argument("--fg-factor", type=int, default=2,
                    help="foreground bytes per round, in rebuild-budget "
                         "multiples (higher = gentler throttle)")
    ap.add_argument("--slo-sessions", type=int, default=24)
    ap.add_argument("--slo-nodes", type=int, default=8)
    ap.add_argument("--slo-rounds", type=int, default=6)
    ap.add_argument("--slo-leaves", type=int, default=8)
    ap.add_argument("--slo-leaf-kib", type=int, default=64)
    ap.add_argument("--slo-ms", type=float, default=5.0,
                    help="p95 restore-latency SLO after the failover")
    ap.add_argument("--seeds", type=int, default=50,
                    help="failure-schedule conformance seeds")
    ap.add_argument("--fleet", default="mixed")
    ap.add_argument("--out", default=str(ARTIFACTS / "ft_bench.json"))
    args = ap.parse_args(argv)

    rows: list[dict] = []
    if args.mode in ("degraded", "all"):
        print(f"=== degraded reads (one engine down, "
              f"{args.degraded_mib} MiB object) ===")
        for oclass in args.oclasses:
            r = degraded(oclass, args.degraded_mib)
            rows.append(r)
            if r["data_loss_raised"]:
                print(f"{oclass:8s} healthy {r['healthy_gib_s']:7.2f} "
                      f"GiB/s  degraded: DataLossError (loud loss)")
            else:
                print(f"{oclass:8s} healthy {r['healthy_gib_s']:7.2f} "
                      f"GiB/s  degraded {r['degraded_gib_s']:7.2f} "
                      f"GiB/s  ({r['ratio']:.0%})")
    if args.mode in ("rebuild", "all"):
        print(f"\n=== rebuild vs foreground ({args.rebuild_mib} MiB "
              f"victim, {args.rebuild_rounds} budget rounds) ===")
        r = rebuild_contention(args.rebuild_mib, args.rebuild_rounds,
                               args.fg_factor)
        rows.append(r)
        print(f"floor {r['rebuild_floor_s'] * 1e3:8.1f} ms  throttled "
              f"{r['rebuild_throttled_s'] * 1e3:8.1f} ms "
              f"({r['slowdown']:.1f}x)  fg {r['fg_base_gib_s']:.2f} -> "
              f"{r['fg_contended_gib_s']:.2f} GiB/s "
              f"({r['fg_retention']:.0%} kept)")
    if args.mode in ("slo", "all"):
        print(f"\n=== serving failover ({args.slo_sessions} sessions x "
              f"{args.slo_nodes} nodes, {args.slo_rounds} waves, node "
              f"dies mid-sweep) ===")
        r = slo_sweep(args.slo_sessions, args.slo_nodes, args.slo_rounds,
                      args.slo_leaves, args.slo_leaf_kib, args.slo_ms)
        rows.append(r)
        print(f"p95 {r['p95_pre_ms']:7.2f} -> {r['p95_post_ms']:7.2f} ms "
              f"(SLO {r['slo_ms']:.0f} ms)  failovers {r['failovers']}  "
              f"dead routed: {r['dead_routed']}")
    if args.mode in ("conform", "all"):
        print(f"\n=== failure-schedule conformance ({args.seeds} seeds, "
              f"fleet {args.fleet}) ===")
        r = conformance(args.seeds, args.fleet)
        rows.append(r)
        print(f"{r['seeds']} seeds  {r['fail_cycles']} failure cycles  "
              f"{r['checked_reads']} checked reads  byte-exact: "
              f"{r['byte_exact']}")
    claims = check_claims(rows)
    if claims:
        print("\n=== Failure-tier claims ===")
        for c in claims:
            print(f"  [{'PASS' if c['ok'] else 'FAIL'}] {c['claim']}   "
                  f"({c['detail']})")
        rows.extend({"mode": "claims", **c} for c in claims)
    pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(args.out).write_text(json.dumps(rows, indent=1))
    print(f"\nsaved {len(rows)} rows -> {args.out}")
    return rows


if __name__ == "__main__":
    main()
