from .kvstore import KVCacheStore, KVStoreError
from .scheduler import NodeState, SchedulerError, ServeScheduler
from .serve_step import (make_decode_step, make_prefill_step,
                         measure_decode_s)

__all__ = ["KVCacheStore", "KVStoreError", "NodeState", "SchedulerError",
           "ServeScheduler", "make_decode_step", "make_prefill_step",
           "measure_decode_s"]
