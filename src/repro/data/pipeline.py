"""Object-store-backed training data pipeline.

The corpus lives in the DAOS-model store as fixed-size token shards (one
array object per shard — the bulk-read pattern of the paper's IOR easy
mode).  The ``Prefetcher`` keeps `depth` shard reads in flight on an event
queue; if the next shard is late (a straggling engine), it *skips ahead* to
any shard that already landed — bounded-staleness straggler mitigation: the
training loop never stalls on one slow server.
"""
from __future__ import annotations

import numpy as np

from ..core import EventQueue
from ..core.interfaces import DFS, make_interface


def write_corpus(dfs: DFS, corpus: np.ndarray, shard_tokens: int = 65536,
                 base: str = "/data", interface: str = "dfs",
                 oclass: str | None = None) -> int:
    iface = make_interface(interface, dfs)
    try:
        dfs.mkdir(base)
    except Exception:
        pass
    n_shards = -(-corpus.size // shard_tokens)
    for s in range(n_shards):
        chunk = corpus[s * shard_tokens: (s + 1) * shard_tokens]
        h = iface.create(f"{base}/shard_{s:06d}.tok", oclass=oclass,
                         client_node=s % 8, process=s)
        h.write_at(0, chunk.astype(np.int32))
    return n_shards


class ObjectStoreDataset:
    def __init__(self, dfs: DFS, base: str = "/data",
                 interface: str = "dfs") -> None:
        self.dfs = dfs
        self.iface = make_interface(interface, dfs)
        self.base = base
        self.shards = sorted(n for n in dfs.readdir(base)
                             if n.startswith("shard_"))
        if not self.shards:
            raise FileNotFoundError(f"no shards under {base}")

    def read_shard(self, idx: int, client_node: int = 0,
                   process: int = 0) -> np.ndarray:
        name = self.shards[idx % len(self.shards)]
        h = self.iface.open(f"{self.base}/{name}", client_node=client_node,
                            process=process)
        raw = h.read_at(0, h.size)
        return np.asarray(raw).view(np.int32)

    def __len__(self) -> int:
        return len(self.shards)


class Prefetcher:
    """Keeps `depth` shard reads in flight; skips stragglers."""

    def __init__(self, ds: ObjectStoreDataset, order: list[int] | None = None,
                 depth: int = 4) -> None:
        self.ds = ds
        self.order = list(order if order is not None else range(len(ds)))
        self.depth = depth
        self.eq = EventQueue(depth=depth)
        self._inflight: list[tuple[int, object]] = []
        self._next = 0
        self.skipped: list[int] = []
        self.failed: list[int] = []
        self._fill()

    def _fill(self) -> None:
        while len(self._inflight) < self.depth and \
                self._next < len(self.order):
            idx = self.order[self._next]
            self._next += 1
            self._inflight.append(
                (idx, self.eq.submit(self.ds.read_shard, idx)))

    def get(self) -> tuple[int, np.ndarray]:
        """Next ready shard — in order if possible, any ready one if the
        head is straggling and others already completed.  A shard that
        fails to read (dead engine, lost data) is dropped and logged —
        the pipeline never stalls training for one shard."""
        while self._inflight:
            head_idx, head_ev = self._inflight[0]
            if not head_ev.test():
                for i, (idx, ev) in enumerate(self._inflight[1:], 1):
                    if ev.test():  # head is a straggler: serve a ready shard
                        self.skipped.append(head_idx)
                        self._inflight.append(self._inflight.pop(0))
                        head_idx, head_ev = self._inflight[0]
                        break
            try:
                data = head_ev.wait()
            except Exception:
                self.failed.append(head_idx)
                self._inflight.pop(0)
                self._fill()
                continue
            self._inflight.pop(0)
            self._fill()
            return head_idx, data
        raise StopIteration

    def batches(self, batch: int, seq: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        buf = np.zeros(0, np.int32)
        while True:
            while buf.size < batch * (seq + 1):
                try:
                    _, shard = self.get()
                except StopIteration:
                    return
                buf = np.concatenate([buf, shard])
            need = batch * seq
            toks = buf[:need].reshape(batch, seq)
            buf = buf[need:]
            yield {"tokens": toks.astype(np.int32)}
