"""Serving step factories: prefill (prompt -> cache) and decode (one token).

These are the functions the decode_* / long_* dry-run cells lower, and what
the serving example drives with batched requests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import forward_decode, forward_prefill
from ..models import layers as L


def make_prefill_step(cfg, pad_to: int | None = None):
    def prefill_step(params, batch):
        hidden, cache = forward_prefill(params, cfg, batch, pad_to=pad_to)
        logits = L.lm_logits(params["embed"], hidden[:, -1:])
        return logits, cache
    return prefill_step


def make_decode_step(cfg, greedy: bool = True):
    def decode_step(params, cache, tokens, pos):
        hidden, cache = forward_decode(params, cfg, cache, tokens, pos)
        logits = L.lm_logits(params["embed"], hidden)
        if greedy:
            next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
                .astype(jnp.int32)
        else:
            next_tok = tokens
        return next_tok, logits, cache
    return decode_step


def measure_decode_s(arch: str = "deepseek-7b", batch: int = 8,
                     prefill_len: int = 32, iters: int = 8,
                     warmup: int = 2) -> float:
    """Wall-clock seconds of one jitted batched decode step (median over
    ``iters`` after ``warmup`` compilation/cache runs).

    This is where the serve benchmark's publish cadence comes from: the
    time a decode fleet actually computes between token steps, measured
    on the smoke variant of a real architecture — instead of a guessed
    ``--think`` constant.  Prefill runs once to build the KV cache the
    step consumes."""
    import time

    import numpy as np

    from ..configs import ARCHS, ShapeConfig, smoke_variant
    from ..models import init_model, make_inputs

    cfg = smoke_variant(ARCHS[arch])
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    shape = ShapeConfig("serve-measure", int(prefill_len), int(batch),
                        "prefill")
    batch_in = make_inputs(key, cfg, shape)
    _hidden, cache = forward_prefill(params, cfg, batch_in)
    step = jax.jit(make_decode_step(cfg))
    tokens = batch_in["tokens"][:, -1:]
    pos = jnp.asarray(int(prefill_len), jnp.int32)
    for _ in range(max(1, int(warmup))):
        _tok, logits, _cache = step(params, cache, tokens, pos)
        jax.block_until_ready(logits)
    times = []
    for _ in range(max(1, int(iters))):
        t0 = time.perf_counter()
        _tok, logits, _cache = step(params, cache, tokens, pos)
        jax.block_until_ready(logits)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))
