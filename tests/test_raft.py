"""RAFT-lite metadata service: elections, quorum, replication, failover."""
import pytest

from repro.core import NoQuorumError, RaftGroup


def test_basic_kv():
    g = RaftGroup(3)
    g.set("a", 1)
    g.set("b", {"x": 2})
    assert g.get("a") == 1
    assert g.get("b") == {"x": 2}
    g.delete("a")
    assert g.get("a") is None


def test_leader_failover_preserves_committed_state():
    g = RaftGroup(3)
    for i in range(20):
        g.set(("k", i), i * i)
    old_leader = g.leader_id
    g.fail_node(old_leader)
    assert g.leader().id != old_leader
    for i in range(20):
        assert g.get(("k", i)) == i * i
    g.set("post", "failover")  # still writable with 2/3
    assert g.get("post") == "failover"


def test_no_quorum_rejects_writes():
    g = RaftGroup(3)
    g.set("a", 1)
    g.fail_node(0)
    g.fail_node(1)
    if g.leader_id is None or not g.nodes[g.leader_id].alive:
        with pytest.raises(NoQuorumError):
            g.leader()
    else:
        with pytest.raises(NoQuorumError):
            g.set("b", 2)
    # committed state still readable from the survivor's log
    assert g.nodes[2].state.get("a") == 1


def test_recovered_node_catches_up():
    g = RaftGroup(3)
    g.set("a", 1)
    g.fail_node(2)
    g.set("b", 2)
    g.set("c", 3)
    g.restore_node(2)
    g.set("d", 4)  # replication to node 2 forces full sync on divergence
    assert g.nodes[2].state.get("d") == 4
    assert g.nodes[2].state.get("b") == 2


def test_five_node_group_survives_two_failures():
    g = RaftGroup(5)
    for i in range(10):
        g.set(i, i)
    g.fail_node(g.leader_id)
    g.fail_node(g.leader().id)
    for i in range(10):
        assert g.get(i) == i
    g.set("still", "alive")
    assert g.get("still") == "alive"
