"""HDF5 over the DFuse mount.

The paper's slowest interface for file-per-process (claim C3).  The costs are
structural, not incidental, and we model each one:

* the HDF5 library serialises data into **chunks** (default here 1 MiB) —
  every chunk is a separate synchronous POSIX op through FUSE;
* B-tree / object-header metadata updates add extra small ops per dataset
  write sequence (``op_multiplier``);
* sync-on-close flushes the superblock (metadata round trips);
* every op pays the same fuse crossing as POSIX (shared daemon resource).

For the shared-file (IOR hard) case HDF5 is driven through its MPI-IO VFD,
so it inherits collective buffering — which is exactly why the paper sees
interfaces converge on shared-file: construct with ``collective=True``.
"""
from __future__ import annotations

from ..object import IOCtx
from .base import AccessInterface, H5_CHUNK  # noqa: F401  (re-export)


class HDF5Interface(AccessInterface):
    name = "hdf5"
    profile_name = "hdf5"

    def __init__(self, dfs, chunk_bytes: int = H5_CHUNK,
                 collective: bool = False, **kw) -> None:
        super().__init__(dfs, **kw)
        self.chunk_bytes = chunk_bytes
        self.collective = collective
        if collective:
            self.name = "hdf5-coll"
            self.profile_name = "hdf5-sfp"

    def make_ctx(self, client_node: int = 0, process: int = 0,
                 transfer_bytes: int = 0) -> IOCtx:
        if self.collective:
            # HDF5 -> MPI-IO VFD -> collective buffering: big aggregated ops,
            # still paying h5 library latency per op.
            return self.profile.ctx(client_node, process)
        return self.profile.ctx(client_node, process,
                                frag_bytes=self.chunk_bytes)

    def create(self, path: str, oclass=None, client_node: int = 0,
               process: int = 0, tx=None):
        h = super().create(path, oclass, client_node, process, tx=tx)
        # file-format bootstrap: superblock + root group + dataset header
        self.dfs.cont.pool.sim.record_md(3)
        h.write_sized_at(0, 2048)               # superblock/header blocks
        return h

    def close(self, handle) -> None:
        # sync-on-close: flush object headers + superblock
        self.dfs.cont.pool.sim.record_md(2)
        handle.obj.write_sized(0, 512, ctx=handle.ctx)
        handle.close()


from .mpiio import MPIIOInterface  # noqa: E402  (at bottom: avoid cycle)


class HDF5CollectiveInterface(MPIIOInterface):
    """HDF5 through its MPI-IO VFD with collective buffering — what a
    shared-file HDF5 run actually does, and why the paper sees interfaces
    converge on IOR hard.  Inherits write_all/read_all aggregation; adds
    the h5 library's per-op latency + metadata chatter."""

    name = "hdf5-coll"

    def make_ctx(self, client_node: int = 0, process: int = 0,
                 transfer_bytes: int = 0) -> IOCtx:
        ctx = super().make_ctx(client_node, process, transfer_bytes)
        ctx.lat_per_op += 70e-6
        ctx.op_multiplier = 1.5
        return ctx
