"""Per-architecture smoke tests (the brief's required reduced-config suite):
one forward/train step + one prefill/decode step on CPU for every assigned
arch, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, shape_applicable, smoke_variant
from repro.configs.base import ShapeConfig
from repro.models import init_model, make_inputs, forward_train, param_count
from repro.serve import make_decode_step, make_prefill_step
from repro.train import make_train_step, opt_init

TRAIN = ShapeConfig("smoke_train", 32, 2, "train")
PREFILL = ShapeConfig("smoke_prefill", 32, 2, "prefill")
KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_finite(arch):
    cfg = smoke_variant(ARCHS[arch])
    params = init_model(KEY, cfg)
    assert param_count(params) > 0
    batch = make_inputs(KEY, cfg, TRAIN)
    hidden, aux = forward_train(params, cfg, batch)
    B = TRAIN.global_batch
    from repro.models import text_len
    S_expect = text_len(cfg, TRAIN.seq_len) + (
        cfg.n_prefix_tokens if cfg.family == "vlm" else 0)
    assert hidden.shape == (B, S_expect, cfg.d_model)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_reduces_loss(arch):
    cfg = smoke_variant(ARCHS[arch])
    params = init_model(KEY, cfg)
    opt = opt_init(cfg.optimizer, params)
    step = jax.jit(make_train_step(cfg))
    batch = make_inputs(KEY, cfg, TRAIN)
    first = None
    for _ in range(4):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) < first


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_shapes(arch):
    cfg = smoke_variant(ARCHS[arch])
    params = init_model(KEY, cfg)
    batch = make_inputs(KEY, cfg, PREFILL)
    logits, cache = make_prefill_step(cfg)(params, batch)
    assert logits.shape[-1] == cfg.padded_vocab()
    nt, lg, cache2 = make_decode_step(cfg)(
        params, cache, jnp.zeros((2, 1), jnp.int32),
        jnp.asarray(PREFILL.seq_len - 1, jnp.int32))
    assert lg.shape[:2] == (2, 1)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_applicability_matrix():
    """40 cells; long_500k runs only for sub-quadratic archs."""
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    runnable = [(a, s) for a, s in cells
                if shape_applicable(ARCHS[a], SHAPES[s])[0]]
    assert len(runnable) == 33
    skipped = sorted(set(cells) - set(runnable))
    assert all(s == "long_500k" for _, s in skipped)
    subq = {a for a, s in runnable if s == "long_500k"}
    assert subq == {"h2o-danube-1.8b", "recurrentgemma-9b", "mamba2-370m"}
