"""Blockwise (flash-style) attention in pure JAX.

Full-sequence attention at 32k+ cannot materialise (S, S) scores; this is
the online-softmax formulation: scan over KV blocks per Q block carrying
(running max, running sum, accumulator).  XLA keeps one (bq, bk) score
block live at a time.

Two iteration schemes:
* full rectangle (causal / bidirectional / prefix): every Q block visits
  every KV block; causal masking is applied per block.  For causal runs
  this computes ~2x the minimal FLOPs — a known baseline cost, listed as a
  hillclimb candidate in EXPERIMENTS.md §Perf.
* windowed (SWA / local attention): each Q block visits a statically-sized
  KV slice [start, start + window + bq) via dynamic_slice — O(S * window).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG = -1e30


def _block_mask(qi0, ki0, bq, bk, *, causal: bool, window: int,
                prefix: int) -> jnp.ndarray:
    """Additive fp32 mask for a (bq, bk) block at global offsets (qi0, ki0)."""
    qi = qi0 + jnp.arange(bq)[:, None]
    ki = ki0 + jnp.arange(bk)[None, :]
    allow = jnp.ones((bq, bk), bool)
    if causal:
        allow &= ki <= qi
    if window:
        allow &= (qi - ki) < window
    if prefix:
        allow |= ki < prefix
    return jnp.where(allow, 0.0, NEG)


def _attend_block(q, k, v, mask):
    """q: (B,Hkv,G,bq,D), k/v: (B,Hkv,bk,D), mask: (bq,bk).
    Returns (scores_exp (..bq,bk) style partials): m, l, acc contribution."""
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s / np.sqrt(q.shape[-1]) + mask
    return s


def blockwise_attention(q, k, v, n_kv: int, *, causal: bool = True,
                        window: int = 0, prefix: int = 0,
                        bq: int = 256, bk: int = 512) -> jnp.ndarray:
    """q: (B,S,Hq,D); k,v: (B,Sk,Hkv,D) -> (B,S,Hq,D).  fp32 accumulators."""
    B, S, Hq, D = q.shape
    Sk = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, Sk)
    if S % bq or Sk % bk:      # smoke shapes: fall back to single block
        bq, bk = S, Sk
    G = Hq // n_kv
    nq, nk = S // bq, Sk // bk

    qb = q.reshape(B, nq, bq, n_kv, G, D).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nk, bk, n_kv, D).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, bk, n_kv, D).transpose(1, 0, 3, 2, 4)

    use_window = bool(window) and Sk > (window + bq)

    def q_block(qi, qblk):
        # qblk: (B,Hkv,G,bq,D)
        m0 = jnp.full((B, n_kv, G, bq), NEG, jnp.float32)
        l0 = jnp.zeros((B, n_kv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, n_kv, G, bq, D), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki0, kblk, vblk = inp
            mask = _block_mask(qi * bq, ki0, bq, kblk.shape[-2],
                               causal=causal, window=window, prefix=prefix)
            s = _attend_block(qblk, kblk, vblk, mask)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            scale = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * scale + jnp.sum(p, axis=-1)
            acc = acc * scale[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l, acc), None

        if use_window:
            # statically-sized KV slice covering [q0 - window, q0 + bq)
            span = window + bq
            span = -(-span // bk) * bk
            start = jnp.clip(qi * bq + bq - span, 0, Sk - span)
            kfull = kb.transpose(1, 2, 0, 3, 4).reshape(B, n_kv, Sk, D)
            vfull = vb.transpose(1, 2, 0, 3, 4).reshape(B, n_kv, Sk, D)
            ksl = jax.lax.dynamic_slice(
                kfull, (0, 0, start, 0), (B, n_kv, span, D))
            vsl = jax.lax.dynamic_slice(
                vfull, (0, 0, start, 0), (B, n_kv, span, D))
            mask = _block_mask(qi * bq, start, bq, span, causal=causal,
                               window=window, prefix=prefix)
            s = _attend_block(qblk, ksl, vsl, mask)
            m = jnp.max(s, axis=-1)
            p = jnp.exp(s - m[..., None])
            l = jnp.sum(p, axis=-1)
            acc = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(vsl.dtype),
                             vsl).astype(jnp.float32)
            out = acc / jnp.maximum(l, 1e-30)[..., None]
        else:
            ki0s = jnp.arange(nk) * bk
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          (ki0s, kb, vb))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # (B,Hkv,G,bq,D)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), qb))          # (nq,B,Hkv,G,bq,D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, Hq, D)
    return out.astype(q.dtype)
