"""The paper's DAOS access mechanisms, as swappable interfaces."""
from .base import (COST_PROFILES, AccessInterface, CostProfile, FileHandle)
from .dfs import DFS, DFSError, DFSInterface, ArrayInterface
from .hdf5 import HDF5CollectiveInterface, HDF5Interface
from .mpiio import MPIIOInterface
from .posix import POSIXInterface


def make_interface(name: str, dfs: DFS) -> AccessInterface:
    """Factory keyed by the names the IOR harness / configs use."""
    table = {
        "dfs": lambda: DFSInterface(dfs),
        "dfs-cached": lambda: DFSInterface(dfs, cache_mode="writeback"),
        "daos-array": lambda: ArrayInterface(dfs),
        "posix": lambda: POSIXInterface(dfs),
        "posix-ioil": lambda: POSIXInterface(dfs, intercept=True),
        "posix-cached": lambda: POSIXInterface(dfs, cache_mode="writeback"),
        "posix-readahead": lambda: POSIXInterface(dfs,
                                                  cache_mode="readahead"),
        "mpiio": lambda: MPIIOInterface(dfs),
        "hdf5": lambda: HDF5Interface(dfs),
        "hdf5-coll": lambda: HDF5CollectiveInterface(dfs),
    }
    try:
        return table[name]()
    except KeyError:
        raise KeyError(f"unknown interface {name!r}; known: {sorted(table)}")


INTERFACE_NAMES = ["dfs", "dfs-cached", "daos-array", "posix", "posix-ioil",
                   "posix-cached", "posix-readahead", "mpiio", "hdf5",
                   "hdf5-coll"]

__all__ = ["AccessInterface", "ArrayInterface", "COST_PROFILES",
           "CostProfile", "DFS", "DFSError", "DFSInterface", "FileHandle",
           "HDF5Interface", "INTERFACE_NAMES", "MPIIOInterface",
           "POSIXInterface", "make_interface"]
