from .pipeline import ObjectStoreDataset, Prefetcher, write_corpus
from .synthetic import synthetic_batch, synthetic_corpus

__all__ = ["ObjectStoreDataset", "Prefetcher", "synthetic_batch",
           "synthetic_corpus", "write_corpus"]
