"""Pools: a set of DAOS engines + the replicated control plane.

The pool owns the engines (real byte stores), the IOSim timing model, and the
RAFT metadata group.  Failure handling follows DAOS semantics:

* ``fail_engine`` / ``fail_node`` bump the pool-map version through RAFT;
* ``rebuild()`` restores redundancy for RP_*/EC_* objects by reconstructing
  the shards that lived on dead engines onto live replacements (recorded as
  per-object layout overrides so placement of surviving shards never moves);
* unprotected (S*) data on a dead engine raises ``DataLossError`` on access —
  the honest failure mode the paper's object classes trade against.
"""
from __future__ import annotations

from . import layout as _layout
from .container import Container
from .engine import Engine, EngineFailedError, NotFoundError
from .raft import RaftGroup
from .simnet import IOSim, Topology, HWProfile


class Pool:
    def __init__(self, topo: Topology | None = None,
                 hw: HWProfile | str | None = None,
                 svc_replicas: int = 3, materialize: bool = True,
                 stripe_cell: int = 1 << 20, label: str = "pool0") -> None:
        self.label = label
        self.topo = topo or Topology()
        self.sim = IOSim(self.topo, hw)
        self.stripe_cell = stripe_cell
        self.engines: dict[int, Engine] = {
            i: Engine(i, self.topo.node_of_engine(i), materialize=materialize)
            for i in self.topo.engine_ids()}
        self.raft = RaftGroup(svc_replicas)
        self.raft.set(("pool", "map_version"), 1)
        self.base_map_version = 1   # object placement seed (stable across fail)
        self.containers: dict[str, Container] = {}

    # ------------- control plane -------------
    @property
    def map_version(self) -> int:
        return self.raft.get(("pool", "map_version"), 1)

    def _bump_map(self) -> None:
        self.raft.set(("pool", "map_version"), self.map_version + 1)

    def create_container(self, label: str, oclass: str = "SX",
                         stripe_cell: int | None = None) -> Container:
        if label in self.containers:
            raise ValueError(f"container {label!r} exists")
        cont = Container(self, label, default_oclass=oclass,
                         stripe_cell=stripe_cell or self.stripe_cell)
        self.containers[label] = cont
        self.raft.set(("cont", label), {"oclass": oclass})
        return cont

    def open_container(self, label: str) -> Container:
        return self.containers[label]

    # ------------- engines / failures -------------
    def all_engine_ids(self) -> list[int]:
        return sorted(self.engines)

    def live_engine_ids(self) -> list[int]:
        return [i for i, e in sorted(self.engines.items()) if e.alive]

    def fail_engine(self, engine_id: int) -> None:
        self.engines[engine_id].fail()
        self._bump_map()

    def fail_node(self, node_id: int) -> list[int]:
        failed = [i for i, e in self.engines.items() if e.node_id == node_id]
        for i in failed:
            self.engines[i].fail()
        self._bump_map()
        return failed

    def restore_engine(self, engine_id: int) -> None:
        """Bring an engine back *empty* (fresh hardware); rebuild must have
        moved its data already."""
        eng = self.engines[engine_id]
        eng.restore()
        eng._store.clear()
        eng.used = 0
        self._bump_map()

    # ------------- rebuild -------------
    def _replacement_for(self, oid: int, dead: int, taken: set[int]) -> int:
        live = [e for e in self.live_engine_ids() if e not in taken]
        if not live:
            # wide layouts (e.g. RP_2GX) already span every engine: reuse a
            # live one — redundancy is restored even if placement overlaps.
            live = self.live_engine_ids()
        if not live:
            raise EngineFailedError("no live engine available for rebuild")
        idx = _layout.jump_hash(_layout.oid_for(oid ^ dead), len(live))
        return live[idx]

    def rebuild(self) -> dict:
        """Restore redundancy after failures. Returns a summary dict."""
        dead = [i for i, e in self.engines.items() if not e.alive]
        moved_cells = 0
        lost_objects = 0
        for cont in self.containers.values():
            for oid in cont.known_oids():
                ocname = cont.object_class_of(oid)
                oc = _layout.get_class(ocname)
                lay = cont.layout_for(oid, oc, cont.stripe_cell)
                dead_targets = [t for t in lay.targets if t in dead]
                if not dead_targets:
                    continue
                if oc.replicas == 1 and not oc.ec_data:
                    lost_objects += 1
                    continue
                from .object import ArrayObject
                obj = ArrayObject(cont, f"oid:{oid:x}", oid, oc,
                                  cont.stripe_cell)
                taken = set(lay.targets)
                for dt in set(dead_targets):
                    repl = self._replacement_for(oid, dt, taken)
                    taken.add(repl)
                    moved_cells += self._copy_shard(cont, obj, lay, dt, repl)
                    moved_cells += self._copy_kv_records(cont, obj, lay, dt,
                                                         repl)
                    cont.set_override(oid, dt, repl)
        return {"dead_engines": dead, "moved_cells": moved_cells,
                "lost_objects": lost_objects}

    def _copy_shard(self, cont: Container, obj, lay, dead: int,
                    replacement: int) -> int:
        """Reconstruct every cell the dead engine held for this object, via
        surviving replicas / EC parity, onto the replacement engine."""
        moved = 0
        size = cont.object_size(obj.oid)
        if size == 0:
            return 0
        n_cells = -(-size // obj.stripe_cell)
        epoch = float(cont.committed_epoch)
        for cn in range(n_cells):
            if obj.oclass.ec_data:
                info = obj._cell_engines(lay, cn)
                homes = (info[0],)
                parity_home = info[1]
            else:
                homes = lay.replicas_for_chunk(cn)
                parity_home = None
            if dead not in homes and dead != parity_home:
                continue
            if dead in homes:
                try:
                    raw = obj._read_cell(lay, cn, epoch)  # degraded path
                except (NotFoundError, KeyError):
                    continue
                self.engines[replacement].update(
                    (cont.label, obj.oid, "arr", cn), raw,
                    int(epoch))
                moved += 1
            elif parity_home == dead and obj.oclass.ec_data:
                k = obj._data_width(lay)
                group = cn // k
                cells = []
                for ln in range(k):
                    try:
                        cells.append(obj._fetch_raw(
                            obj._cell_engines(lay, group * k + ln)[0],
                            group * k + ln, epoch))
                    except (NotFoundError, KeyError, EngineFailedError):
                        pass
                from . import redundancy
                parity = redundancy.xor_parity(cells, obj.stripe_cell)
                self.engines[replacement].update(
                    (cont.label, obj.oid, "par", group), parity, int(epoch))
                moved += 1
        return moved

    def _copy_kv_records(self, cont: Container, obj, lay, dead: int,
                         replacement: int) -> int:
        """Restore KV records (dir entries, manifests) whose replica set
        included the dead engine, from any surviving replica."""
        moved = 0
        seen: set = set()
        for eid in set(lay.targets):
            eng = self.engines.get(eid)
            if eng is None or not eng.alive:
                continue
            for key in list(eng.keys((cont.label, obj.oid))):
                dkey = key[2]
                if dkey in ("arr", "par") or key in seen:
                    continue
                h = _layout.oid_for(str(dkey), container_seq=17)
                reps = lay.replicas_for_chunk(h % lay.width)
                if dead not in reps:
                    continue
                seen.add(key)
                for epoch, rec in eng.records(key).items():
                    if rec.data is None:
                        self.engines[replacement].update_hole(
                            key, rec.length, epoch)
                    else:
                        self.engines[replacement].update(
                            key, rec.data, epoch, csum=rec.csum)
                moved += 1
        return moved

    # ------------- stats -------------
    def stats(self) -> dict:
        return {
            "map_version": self.map_version,
            "engines": [e.stats() for e in self.engines.values()],
            "containers": sorted(self.containers),
            "sim_time": self.sim.clock.now,
        }
